//! `.arbf` — the approxrbf binary model artifact format.
//!
//! A compact, versioned, checksummed little-endian encoding for
//! [`SvmModel`], [`ApproxModel`], their quantized twins
//! ([`QuantSvmModel`] / [`QuantApproxModel`], kind-4 f16 and kind-5
//! int8 records, advertised by the [`FLAG_QUANT_F16`] /
//! [`FLAG_QUANT_INT8`] header bits) and the per-tenant
//! [`TenantPolicy`] (kind-3 record, advertised by the
//! [`FLAG_HAS_POLICY`] header bit), sitting alongside the text codecs
//! (LIBSVM text / `approx_type maclaurin2_rbf`) that Table 3 measures.
//! Design goals, in order: **integrity** (magic + version + per-record
//! CRC-32, truncation-safe reads, strict non-finite rejection — every
//! failure is a typed [`Error::Corrupt`]), **compactness** (4-byte f32
//! payloads — 2-byte f16 / 1-byte int8 when quantized —
//! upper-triangle-only `M`, LIBSVM-style sparse SV rows) and
//! **cheap introspection** (generation/dim/n_sv/payload-kind live in
//! the fixed 32-byte file header so the registry can poll for
//! hot-swaps without deserializing payloads).
//!
//! Byte-exact layout: `docs/FORMATS.md`; the committed golden corpus
//! under `rust/tests/data/` plus `rust/tests/format_conformance.rs`
//! pin every byte of it. Encoders refuse non-finite values with
//! [`Error::InvalidArg`]; decoders re-run the same validation
//! ([`SvmModel::check_finite`] / [`ApproxModel::check_finite`] /
//! [`QuantSvmModel::check`] / [`QuantApproxModel::check`]) and report
//! [`Error::Corrupt`].
//!
//! Two container versions share the record vocabulary. [`FORMAT_V1`]
//! (the default, byte-pinned by the golden corpus) packs records
//! back-to-back and always decodes to the heap. [`FORMAT_V2`] writes
//! every payload at a committed [`PAYLOAD_ALIGN`]-byte file offset —
//! the record header's reserved word becomes the zero-filled pad
//! count — and lays quantized/rff tensor segments out dense and
//! aligned, so [`decode_bundle_mapped`] can serve
//! [`TensorData`](super::mapfile::TensorData) views straight over a
//! memory-mapped file with zero copies and bit-identical results.

#![forbid(unsafe_code)]

use std::sync::Arc;
use std::time::Duration;

use crate::approx::{ApproxModel, RffModel};
use crate::coordinator::{RoutePolicy, TenantPolicy};
use crate::linalg::Mat;
use crate::svm::{Kernel, SvmModel};
use crate::util::crc32::crc32;
use crate::{Error, Result};

use super::mapfile::{MapFile, MapSlice, TensorData};
use super::quant::{
    PayloadKind, QuantApproxModel, QuantMat, QuantSvmModel, QuantSymData,
    QuantSymMat, QuantVec, TenantModels,
};

/// File magic: `ARBF`.
pub const MAGIC: [u8; 4] = *b"ARBF";
/// Format version written by default (alias of [`FORMAT_V1`]).
pub const VERSION: u16 = 1;
/// Container format version 1: records packed back-to-back, payloads
/// decoded to the heap. The default; byte-pinned by the golden corpus.
pub const FORMAT_V1: u16 = 1;
/// Container format version 2: same record kinds, CRC discipline and
/// payload semantics as v1, but every payload starts on a
/// [`PAYLOAD_ALIGN`]-byte file offset (the record header's reserved
/// word carries the pad count) and quantized/rff tensor segments are
/// dense and aligned within the payload, so a decoder can hand out
/// views directly over a memory-mapped file.
pub const FORMAT_V2: u16 = 2;
/// Committed payload alignment of format v2, in bytes (one cache
/// line; enough for every tensor element type and future SIMD loads).
/// Pinned equal to [`super::mapfile::PAYLOAD_ALIGN`] by a unit test.
pub const PAYLOAD_ALIGN: usize = 64;
/// Fixed file header length in bytes.
pub const FILE_HEADER_LEN: usize = 32;
/// Fixed per-record header length in bytes.
pub const RECORD_HEADER_LEN: usize = 16;

/// Header flag bit: the file carries a kind-3 tenant-policy record.
/// Lives in the (previously reserved, ignored-on-read) trailing header
/// word, so version-1 readers that predate policies still read these
/// files.
pub const FLAG_HAS_POLICY: u64 = 1;
/// Header flag bit: model payloads are kind-4 (f16) records.
pub const FLAG_QUANT_F16: u64 = 1 << 1;
/// Header flag bit: model payloads are kind-5 (int8) records.
pub const FLAG_QUANT_INT8: u64 = 1 << 2;
/// Header flag bit: the bundle carries a kind-6 random-feature record
/// alongside its f32 exact/approx pair. Mutually exclusive with the
/// quantization bits (no encoder writes both substrates).
pub const FLAG_RFF: u64 = 1 << 3;
/// Version of the kind-3 policy record payload when no per-tenant
/// drift tolerance is set (19-byte body — the original layout, kept
/// byte-stable so every pre-existing bundle and golden fixture still
/// encodes identically).
pub const POLICY_PAYLOAD_VERSION: u16 = 1;
/// Version of the kind-3 policy record payload carrying a per-tenant
/// `quant_drift_tol` (23-byte body: the v1 fields + a trailing f32).
/// Written only when the tolerance is set; decoders accept both.
pub const POLICY_PAYLOAD_VERSION_DRIFT: u16 = 2;

const KIND_SVM: u16 = 1;
const KIND_APPROX: u16 = 2;
const KIND_POLICY: u16 = 3;
const KIND_QUANT_F16: u16 = 4;
const KIND_QUANT_INT8: u16 = 5;
const KIND_RFF: u16 = 6;
/// Role byte leading every kind-4/5 payload: which model the record
/// quantizes.
const ROLE_SVM: u8 = 1;
const ROLE_APPROX: u8 = 2;
/// Sanity cap: a file holds at most this many records (bundles use 2).
const MAX_RECORDS: u16 = 16;
/// Sanity cap on the dense element count (`n_sv × d`) of a decoded SVM
/// record. The sparse row encoding means `d` is not bounded by the
/// payload size, so without this a crafted header could demand a
/// multi-gigabyte allocation; 2²⁸ f32s (1 GiB) is far above any model
/// this repo produces (wide profile: ~1500 × 2000 ≈ 3M).
const MAX_MODEL_ELEMS: u64 = 1 << 28;

/// Container format selector: v1 (packed, heap-decoded — the default)
/// or v2 (aligned payloads a mapped decoder serves zero-copy).
/// Parsed from the CLI `--format` flag and the `APPROXRBF_TEST_FORMAT`
/// environment variable as `"v1"` / `"v2"`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum FormatVersion {
    #[default]
    V1,
    V2,
}

impl FormatVersion {
    /// The on-disk header version number ([`FORMAT_V1`] /
    /// [`FORMAT_V2`]).
    pub fn number(self) -> u16 {
        match self {
            FormatVersion::V1 => FORMAT_V1,
            FormatVersion::V2 => FORMAT_V2,
        }
    }
}

impl std::fmt::Display for FormatVersion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "v{}", self.number())
    }
}

impl std::str::FromStr for FormatVersion {
    type Err = Error;

    fn from_str(s: &str) -> Result<FormatVersion> {
        match s.trim().to_ascii_lowercase().as_str() {
            "v1" | "1" => Ok(FormatVersion::V1),
            "v2" | "2" => Ok(FormatVersion::V2),
            other => Err(Error::InvalidArg(format!(
                "unknown format version {other:?} (expected \"v1\" or \
                 \"v2\")"
            ))),
        }
    }
}

/// Parsed fixed-size file header (the part [`peek_header`] reads
/// without touching payloads).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArbfHeader {
    pub version: u16,
    pub n_records: u16,
    /// Publish generation (0 for standalone single-model files).
    pub generation: u64,
    /// Feature dimension shared by every record in the file.
    pub dim: u32,
    /// Support-vector count of the exact record (0 if none).
    pub n_sv: u32,
    /// Flag bits (see [`FLAG_HAS_POLICY`]); unknown bits are ignored.
    pub flags: u64,
}

impl ArbfHeader {
    /// True iff the header advertises a kind-3 policy record.
    pub fn has_policy(&self) -> bool {
        self.flags & FLAG_HAS_POLICY != 0
    }

    /// True iff the header advertises a kind-6 random-feature record.
    pub fn has_rff(&self) -> bool {
        self.flags & FLAG_RFF != 0
    }

    /// Container format as an enum ([`peek_header`] already rejected
    /// every version other than [`FORMAT_V1`] / [`FORMAT_V2`]).
    pub fn format(&self) -> FormatVersion {
        if self.version == FORMAT_V2 {
            FormatVersion::V2
        } else {
            FormatVersion::V1
        }
    }

    /// Payload precision advertised by the header flags (the full
    /// decode cross-checks this against the actual record kinds).
    pub fn payload(&self) -> PayloadKind {
        if self.flags & FLAG_QUANT_F16 != 0 {
            PayloadKind::F16
        } else if self.flags & FLAG_QUANT_INT8 != 0 {
            PayloadKind::Int8
        } else {
            PayloadKind::F32
        }
    }
}

/// One decoded record.
#[derive(Clone, Debug)]
pub enum ModelRecord {
    Svm(SvmModel),
    Approx(ApproxModel),
    /// Per-tenant serving policy (kind 3).
    Policy(TenantPolicy),
    /// Quantized exact model (kind 4/5, role 1), in native storage.
    QuantSvm(QuantSvmModel),
    /// Quantized approx model (kind 4/5, role 2), in native storage.
    QuantApprox(QuantApproxModel),
    /// Random-feature substrate (kind 6): folded weights + the seed the
    /// feature map regenerates from.
    Rff(RffModel),
}

/// A fully decoded registry bundle: the (exact, approx) pair in
/// whatever precision it was published with, plus the optional policy.
#[derive(Clone, Debug)]
pub struct Bundle {
    pub generation: u64,
    /// Container format the bundle was decoded from — rollback and
    /// `migrate` re-encode at this format so an archived generation
    /// reverts byte-faithfully.
    pub format: FormatVersion,
    /// The model pair — f32 or native quantized storage.
    pub models: TenantModels,
    /// Per-tenant serving policy, when the bundle carries one.
    pub policy: Option<TenantPolicy>,
}

impl Bundle {
    pub fn payload(&self) -> PayloadKind {
        self.models.payload()
    }

    /// Dequantized exact model (a clone for f32 bundles).
    pub fn exact_dequant(&self) -> SvmModel {
        self.models.exact_dequant()
    }

    /// Dequantized approx model (a clone for f32 bundles).
    pub fn approx_dequant(&self) -> ApproxModel {
        self.models.approx_dequant()
    }
}

// ---------------------------------------------------------------------
// encode
// ---------------------------------------------------------------------

// Shared with `crate::net::wire`, which reuses the same little-endian
// primitive codec for its frame payloads.
pub(crate) fn push_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn svm_payload(model: &SvmModel) -> Result<Vec<u8>> {
    model.check_finite().map_err(Error::InvalidArg)?;
    let (tag, gamma, beta) = match model.kernel {
        Kernel::Linear => (0u8, 0.0f32, 0.0f32),
        Kernel::Rbf { gamma } => (1, gamma, 0.0),
        Kernel::Poly2 { gamma, beta } => (2, gamma, beta),
    };
    let (n_sv, d) = (model.n_sv(), model.dim());
    let mut out = Vec::new();
    out.push(tag);
    push_f32(&mut out, gamma);
    push_f32(&mut out, beta);
    push_f32(&mut out, model.b);
    push_u32(&mut out, n_sv as u32);
    push_u32(&mut out, d as u32);
    for &c in &model.coef {
        push_f32(&mut out, c);
    }
    // LIBSVM-style sparse rows: (nnz, then nnz × (0-based idx, value)).
    for i in 0..n_sv {
        let row = model.sv.row(i);
        let nnz = row.iter().filter(|&&v| v != 0.0).count();
        push_u32(&mut out, nnz as u32);
        for (j, &v) in row.iter().enumerate() {
            if v != 0.0 {
                push_u32(&mut out, j as u32);
                push_f32(&mut out, v);
            }
        }
    }
    Ok(out)
}

fn approx_payload(am: &ApproxModel) -> Result<Vec<u8>> {
    am.check_finite().map_err(Error::InvalidArg)?;
    let d = am.dim();
    let mut out = Vec::new();
    push_u32(&mut out, d as u32);
    push_f32(&mut out, am.gamma);
    push_f32(&mut out, am.b);
    push_f32(&mut out, am.c);
    push_f32(&mut out, am.max_sv_norm_sq);
    for &v in &am.v {
        push_f32(&mut out, v);
    }
    // M is symmetric: upper triangle, row-wise (matches the text codec).
    for r in 0..d {
        for c in r..d {
            push_f32(&mut out, am.m.at(r, c));
        }
    }
    Ok(out)
}

/// Serialize a [`TenantPolicy`] as a kind-3 record payload.
/// `0` encodes "unset" for every optional field (a zero `max_wait` is
/// meaningless operationally, so nothing is lost). Policies without a
/// `quant_drift_tol` keep the original 19-byte v1 body — bundles that
/// predate the field re-encode byte-identically — and only a set
/// tolerance promotes the record to the 23-byte v2 body.
fn policy_payload(p: &TenantPolicy) -> Vec<u8> {
    let mut out = Vec::with_capacity(23);
    push_u16(
        &mut out,
        match p.quant_drift_tol {
            None => POLICY_PAYLOAD_VERSION,
            Some(_) => POLICY_PAYLOAD_VERSION_DRIFT,
        },
    );
    out.push(match p.route {
        None => 0u8,
        Some(RoutePolicy::AlwaysApprox) => 1,
        Some(RoutePolicy::AlwaysExact) => 2,
        Some(RoutePolicy::Hybrid) => 3,
    });
    push_u32(&mut out, p.max_batch.unwrap_or(0) as u32);
    push_u64(
        &mut out,
        p.max_wait.map(|d| d.as_micros() as u64).unwrap_or(0),
    );
    push_u32(&mut out, p.max_resident_hint);
    if let Some(tol) = p.quant_drift_tol {
        push_f32(&mut out, tol);
    }
    out
}

/// Kind-4/5 role-1 payload: the exact model with quantized
/// coefficients and sparse quantized SV rows (layout: FORMATS.md).
fn quant_svm_payload(m: &QuantSvmModel) -> Vec<u8> {
    let (tag, gamma, beta) = match m.kernel {
        Kernel::Linear => (0u8, 0.0f32, 0.0f32),
        Kernel::Rbf { gamma } => (1, gamma, 0.0),
        Kernel::Poly2 { gamma, beta } => (2, gamma, beta),
    };
    let (n_sv, d) = (m.n_sv(), m.dim());
    let mut out = Vec::new();
    out.push(ROLE_SVM);
    out.push(tag);
    push_f32(&mut out, gamma);
    push_f32(&mut out, beta);
    push_f32(&mut out, m.b);
    push_u32(&mut out, n_sv as u32);
    push_u32(&mut out, d as u32);
    match &m.coef {
        QuantVec::F16(h) => {
            for &x in h.iter() {
                push_u16(&mut out, x);
            }
        }
        QuantVec::Int8 { scale, q } => {
            push_f32(&mut out, *scale);
            for &x in q.iter() {
                out.push(x as u8);
            }
        }
    }
    // Sparse rows mirror the f32 encoding; a "zero" is a zero-valued
    // quantized element (±0 for f16, q = 0 for int8). Int8 rows carry
    // their scale even when empty, so dense reconstruction is exact.
    match &m.sv {
        QuantMat::F16 { rows, cols, h } => {
            for r in 0..*rows {
                let row = &h[r * cols..(r + 1) * cols];
                let nnz = row.iter().filter(|&&x| x & 0x7fff != 0).count();
                push_u32(&mut out, nnz as u32);
                for (j, &x) in row.iter().enumerate() {
                    if x & 0x7fff != 0 {
                        push_u32(&mut out, j as u32);
                        push_u16(&mut out, x);
                    }
                }
            }
        }
        QuantMat::Int8 { rows, cols, scales, q } => {
            for r in 0..*rows {
                let row = &q[r * cols..(r + 1) * cols];
                let nnz = row.iter().filter(|&&x| x != 0).count();
                push_u32(&mut out, nnz as u32);
                push_f32(&mut out, scales[r]);
                for (j, &x) in row.iter().enumerate() {
                    if x != 0 {
                        push_u32(&mut out, j as u32);
                        out.push(x as u8);
                    }
                }
            }
        }
    }
    out
}

/// Kind-4/5 role-2 payload: the approx model with quantized `v` and
/// packed upper-triangle `M` (layout: FORMATS.md). Scalars stay f32.
fn quant_approx_payload(a: &QuantApproxModel) -> Vec<u8> {
    let d = a.dim();
    let mut out = Vec::new();
    out.push(ROLE_APPROX);
    push_u32(&mut out, d as u32);
    push_f32(&mut out, a.gamma);
    push_f32(&mut out, a.b);
    push_f32(&mut out, a.c);
    push_f32(&mut out, a.max_sv_norm_sq);
    match &a.v {
        QuantVec::F16(h) => {
            for &x in h.iter() {
                push_u16(&mut out, x);
            }
        }
        QuantVec::Int8 { scale, q } => {
            push_f32(&mut out, *scale);
            for &x in q.iter() {
                out.push(x as u8);
            }
        }
    }
    match &a.m.data {
        QuantSymData::F16(h) => {
            for &x in h.iter() {
                push_u16(&mut out, x);
            }
        }
        QuantSymData::Int8 { scales, q } => {
            for &s in scales.iter() {
                push_f32(&mut out, s);
            }
            for &x in q.iter() {
                out.push(x as u8);
            }
        }
    }
    out
}

/// Kind-6 payload: the stored half of a random-feature model —
/// `dim:u32, D:u32, seed:u64, γ:f32, bias:f32, err_est:f32, w: D×f32`
/// (28 + 4·D bytes). `W` and `φ` are *not* stored; they regenerate
/// deterministically from the seed (see [`RffModel::from_parts`]).
fn rff_payload(m: &RffModel) -> Vec<u8> {
    let mut out = Vec::with_capacity(28 + 4 * m.n_features());
    push_u32(&mut out, m.dim() as u32);
    push_u32(&mut out, m.n_features() as u32);
    push_u64(&mut out, m.seed);
    push_f32(&mut out, m.gamma);
    push_f32(&mut out, m.bias);
    push_f32(&mut out, m.err_est);
    for &x in m.w.iter() {
        push_f32(&mut out, x);
    }
    out
}

/// Zero-fill `out` up to the next [`PAYLOAD_ALIGN`] boundary, relative
/// to the payload start — which format v2 places on an absolute
/// 64-byte file offset, so relative alignment *is* absolute alignment.
fn pad_payload(out: &mut Vec<u8>) {
    let end = out.len().next_multiple_of(PAYLOAD_ALIGN);
    out.resize(end, 0);
}

/// Format-v2 kind-4/5 role-1 payload: the same scalar prefix as v1,
/// then each tensor segment — coefficients, int8 per-row SV scales,
/// and a **dense row-major** SV matrix — zero-padded to a 64-byte
/// boundary so a mapped decoder can serve typed views straight from
/// the file. v2 trades v1's sparse row encoding for mappability.
fn quant_svm_payload_v2(m: &QuantSvmModel) -> Vec<u8> {
    let (tag, gamma, beta) = match m.kernel {
        Kernel::Linear => (0u8, 0.0f32, 0.0f32),
        Kernel::Rbf { gamma } => (1, gamma, 0.0),
        Kernel::Poly2 { gamma, beta } => (2, gamma, beta),
    };
    let (n_sv, d) = (m.n_sv(), m.dim());
    let mut out = Vec::new();
    out.push(ROLE_SVM);
    out.push(tag);
    push_f32(&mut out, gamma);
    push_f32(&mut out, beta);
    push_f32(&mut out, m.b);
    push_u32(&mut out, n_sv as u32);
    push_u32(&mut out, d as u32);
    match &m.coef {
        QuantVec::F16(h) => {
            pad_payload(&mut out);
            for &x in h.iter() {
                push_u16(&mut out, x);
            }
        }
        QuantVec::Int8 { scale, q } => {
            push_f32(&mut out, *scale);
            pad_payload(&mut out);
            for &x in q.iter() {
                out.push(x as u8);
            }
        }
    }
    pad_payload(&mut out);
    match &m.sv {
        QuantMat::F16 { h, .. } => {
            for &x in h.iter() {
                push_u16(&mut out, x);
            }
        }
        QuantMat::Int8 { scales, q, .. } => {
            for &s in scales.iter() {
                push_f32(&mut out, s);
            }
            pad_payload(&mut out);
            for &x in q.iter() {
                out.push(x as u8);
            }
        }
    }
    out
}

/// Format-v2 kind-4/5 role-2 payload: v1's scalar prefix, then `v`,
/// the int8 per-row `M` scales and the packed upper-triangle `M`
/// each zero-padded to a 64-byte boundary (same reasoning as
/// [`quant_svm_payload_v2`]; the v1 role-2 layout was already dense).
fn quant_approx_payload_v2(a: &QuantApproxModel) -> Vec<u8> {
    let d = a.dim();
    let mut out = Vec::new();
    out.push(ROLE_APPROX);
    push_u32(&mut out, d as u32);
    push_f32(&mut out, a.gamma);
    push_f32(&mut out, a.b);
    push_f32(&mut out, a.c);
    push_f32(&mut out, a.max_sv_norm_sq);
    match &a.v {
        QuantVec::F16(h) => {
            pad_payload(&mut out);
            for &x in h.iter() {
                push_u16(&mut out, x);
            }
        }
        QuantVec::Int8 { scale, q } => {
            push_f32(&mut out, *scale);
            pad_payload(&mut out);
            for &x in q.iter() {
                out.push(x as u8);
            }
        }
    }
    pad_payload(&mut out);
    match &a.m.data {
        QuantSymData::F16(h) => {
            for &x in h.iter() {
                push_u16(&mut out, x);
            }
        }
        QuantSymData::Int8 { scales, q } => {
            for &s in scales.iter() {
                push_f32(&mut out, s);
            }
            pad_payload(&mut out);
            for &x in q.iter() {
                out.push(x as u8);
            }
        }
    }
    out
}

/// Format-v2 kind-6 payload: the same 28-byte prefix as v1 (so
/// [`peek_rff_summary`] serves both formats unchanged), then the
/// folded weight vector zero-padded onto a 64-byte boundary.
fn rff_payload_v2(m: &RffModel) -> Vec<u8> {
    let mut out = Vec::with_capacity(PAYLOAD_ALIGN + 4 * m.n_features());
    push_u32(&mut out, m.dim() as u32);
    push_u32(&mut out, m.n_features() as u32);
    push_u64(&mut out, m.seed);
    push_f32(&mut out, m.gamma);
    push_f32(&mut out, m.bias);
    push_f32(&mut out, m.err_est);
    pad_payload(&mut out);
    for &x in m.w.iter() {
        push_f32(&mut out, x);
    }
    out
}

fn write_file(
    format: FormatVersion,
    generation: u64,
    dim: usize,
    n_sv: usize,
    flags: u64,
    records: Vec<(u16, Vec<u8>)>,
) -> Vec<u8> {
    let total: usize = records
        .iter()
        .map(|(_, p)| RECORD_HEADER_LEN + PAYLOAD_ALIGN + p.len())
        .sum();
    let mut out = Vec::with_capacity(FILE_HEADER_LEN + total);
    out.extend_from_slice(&MAGIC);
    push_u16(&mut out, format.number());
    push_u16(&mut out, records.len() as u16);
    push_u64(&mut out, generation);
    push_u32(&mut out, dim as u32);
    push_u32(&mut out, n_sv as u32);
    push_u64(&mut out, flags);
    for (kind, payload) in records {
        push_u16(&mut out, kind);
        // v1: reserved, always 0 (and ignored on read). v2: the count
        // of zero bytes inserted after this header so the payload
        // lands on the next PAYLOAD_ALIGN-byte file offset.
        let pad = match format {
            FormatVersion::V1 => 0,
            FormatVersion::V2 => {
                // 14 header bytes still to write: pad, crc, length.
                let header_end = out.len() + 14;
                header_end.next_multiple_of(PAYLOAD_ALIGN) - header_end
            }
        };
        push_u16(&mut out, pad as u16);
        push_u32(&mut out, crc32(&payload));
        push_u64(&mut out, payload.len() as u64);
        out.resize(out.len() + pad, 0);
        out.extend_from_slice(&payload);
    }
    out
}

/// Encode a standalone exact model (one record, generation 0).
/// Always format v1: standalone files hold f32 payloads, which serve
/// from the heap in either format.
pub fn encode_svm(model: &SvmModel) -> Result<Vec<u8>> {
    let payload = svm_payload(model)?;
    Ok(write_file(
        FormatVersion::V1,
        0,
        model.dim(),
        model.n_sv(),
        0,
        vec![(KIND_SVM, payload)],
    ))
}

/// Encode a standalone approximated model (one record, generation 0).
pub fn encode_approx(am: &ApproxModel) -> Result<Vec<u8>> {
    let payload = approx_payload(am)?;
    Ok(write_file(
        FormatVersion::V1,
        0,
        am.dim(),
        0,
        0,
        vec![(KIND_APPROX, payload)],
    ))
}

/// Encode a registry bundle: the exact model followed by its
/// approximation, stamped with a publish generation.
pub fn encode_bundle(
    generation: u64,
    exact: &SvmModel,
    approx: &ApproxModel,
) -> Result<Vec<u8>> {
    encode_bundle_with(generation, exact, approx, None)
}

/// [`encode_bundle`] plus an optional kind-3 [`TenantPolicy`] record
/// (advertised via [`FLAG_HAS_POLICY`] in the header).
pub fn encode_bundle_with(
    generation: u64,
    exact: &SvmModel,
    approx: &ApproxModel,
    policy: Option<&TenantPolicy>,
) -> Result<Vec<u8>> {
    encode_bundle_native(
        generation,
        &TenantModels::F32 {
            exact: exact.clone(),
            approx: approx.clone(),
        },
        policy,
    )
}

/// [`encode_bundle_with`] at a chosen payload precision: `F32` writes
/// kind-1/2 records, `F16`/`Int8` quantize both models fresh into
/// kind-4/5 records (the publish path; CLI `registry publish
/// --quantize`).
pub fn encode_bundle_quantized(
    generation: u64,
    exact: &SvmModel,
    approx: &ApproxModel,
    policy: Option<&TenantPolicy>,
    payload: PayloadKind,
) -> Result<Vec<u8>> {
    encode_bundle_quantized_at(
        generation,
        exact,
        approx,
        policy,
        payload,
        FormatVersion::V1,
    )
}

/// [`encode_bundle_quantized`] at an explicit container format — the
/// publish path behind `registry publish --format v2` and
/// `PublishOptions::format`.
pub fn encode_bundle_quantized_at(
    generation: u64,
    exact: &SvmModel,
    approx: &ApproxModel,
    policy: Option<&TenantPolicy>,
    payload: PayloadKind,
    format: FormatVersion,
) -> Result<Vec<u8>> {
    // Dimension agreement is enforced once, by encode_bundle_native_at.
    match payload {
        PayloadKind::F32 => encode_bundle_native_at(
            generation,
            &TenantModels::F32 {
                exact: exact.clone(),
                approx: approx.clone(),
            },
            policy,
            format,
        ),
        kind => encode_bundle_native_at(
            generation,
            &TenantModels::Quantized {
                exact: QuantSvmModel::quantize(exact, kind)?,
                approx: QuantApproxModel::quantize(approx, kind)?,
            },
            policy,
            format,
        ),
    }
}

/// Encode a random-feature bundle: the f32 exact/approx pair (kept so
/// the exact escort path and the Maclaurin twin survive a republish)
/// plus the kind-6 record, advertised via [`FLAG_RFF`]. The publish
/// path for `registry publish --substrate rff`.
pub fn encode_bundle_rff(
    generation: u64,
    exact: &SvmModel,
    approx: &ApproxModel,
    rff: &RffModel,
    policy: Option<&TenantPolicy>,
) -> Result<Vec<u8>> {
    encode_bundle_rff_at(
        generation,
        exact,
        approx,
        rff,
        policy,
        FormatVersion::V1,
    )
}

/// [`encode_bundle_rff`] at an explicit container format.
pub fn encode_bundle_rff_at(
    generation: u64,
    exact: &SvmModel,
    approx: &ApproxModel,
    rff: &RffModel,
    policy: Option<&TenantPolicy>,
    format: FormatVersion,
) -> Result<Vec<u8>> {
    encode_bundle_native_at(
        generation,
        &TenantModels::Rff {
            exact: exact.clone(),
            approx: approx.clone(),
            rff: rff.clone(),
        },
        policy,
        format,
    )
}

/// Encode a bundle from whatever storage the models already hold —
/// **lossless** for quantized models (stored q-values and scales are
/// written verbatim, never re-quantized). This is the rollback path
/// (an archived int8 bundle reverts without double-quantization) and
/// the byte-stability contract the format-conformance corpus pins:
/// `encode_bundle_native(decode(x)) == x`.
pub fn encode_bundle_native(
    generation: u64,
    models: &TenantModels,
    policy: Option<&TenantPolicy>,
) -> Result<Vec<u8>> {
    encode_bundle_native_at(generation, models, policy, FormatVersion::V1)
}

/// [`encode_bundle_native`] at an explicit container format. The same
/// lossless guarantee holds per format: `encode_bundle_native_at(
/// decode(x), x.format) == x` for every well-formed `x`.
pub fn encode_bundle_native_at(
    generation: u64,
    models: &TenantModels,
    policy: Option<&TenantPolicy>,
    format: FormatVersion,
) -> Result<Vec<u8>> {
    let (mut records, mut flags) = match models {
        TenantModels::F32 { exact, approx } => {
            if exact.dim() != approx.dim() {
                return Err(Error::Shape(format!(
                    "bundle: exact dim {} vs approx dim {}",
                    exact.dim(),
                    approx.dim()
                )));
            }
            let sp = svm_payload(exact)?;
            let ap = approx_payload(approx)?;
            (vec![(KIND_SVM, sp), (KIND_APPROX, ap)], 0u64)
        }
        TenantModels::Rff { exact, approx, rff } => {
            if exact.dim() != approx.dim() || exact.dim() != rff.dim() {
                return Err(Error::Shape(format!(
                    "bundle: exact dim {} vs approx dim {} vs rff dim {}",
                    exact.dim(),
                    approx.dim(),
                    rff.dim()
                )));
            }
            let sp = svm_payload(exact)?;
            let ap = approx_payload(approx)?;
            let rp = match format {
                FormatVersion::V1 => rff_payload(rff),
                FormatVersion::V2 => rff_payload_v2(rff),
            };
            (
                vec![(KIND_SVM, sp), (KIND_APPROX, ap), (KIND_RFF, rp)],
                FLAG_RFF,
            )
        }
        TenantModels::Quantized { exact, approx } => {
            if exact.dim() != approx.dim() {
                return Err(Error::Shape(format!(
                    "bundle: exact dim {} vs approx dim {}",
                    exact.dim(),
                    approx.dim()
                )));
            }
            if exact.payload() != approx.payload() {
                return Err(Error::InvalidArg(format!(
                    "bundle: exact payload {} vs approx payload {}",
                    exact.payload(),
                    approx.payload()
                )));
            }
            exact.check().map_err(Error::InvalidArg)?;
            approx.check().map_err(Error::InvalidArg)?;
            let (kind, flag) = match exact.payload() {
                PayloadKind::F16 => (KIND_QUANT_F16, FLAG_QUANT_F16),
                PayloadKind::Int8 => (KIND_QUANT_INT8, FLAG_QUANT_INT8),
                PayloadKind::F32 => unreachable!("quantized storage"),
            };
            let (sp, ap) = match format {
                FormatVersion::V1 => (
                    quant_svm_payload(exact),
                    quant_approx_payload(approx),
                ),
                FormatVersion::V2 => (
                    quant_svm_payload_v2(exact),
                    quant_approx_payload_v2(approx),
                ),
            };
            (vec![(kind, sp), (kind, ap)], flag)
        }
    };
    if let Some(p) = policy {
        if let Some(tol) = p.quant_drift_tol {
            if !tol.is_finite() || tol < 0.0 {
                return Err(Error::InvalidArg(format!(
                    "policy quant_drift_tol must be finite and >= 0, \
                     got {tol}"
                )));
            }
        }
        records.push((KIND_POLICY, policy_payload(p)));
        flags |= FLAG_HAS_POLICY;
    }
    Ok(write_file(
        format,
        generation,
        models.dim(),
        models.n_sv(),
        flags,
        records,
    ))
}

// ---------------------------------------------------------------------
// decode
// ---------------------------------------------------------------------

/// Truncation-safe little-endian reader: every read names what it was
/// reading so corruption errors localize the damage. Shared with
/// `crate::net::wire`, which decodes frame payloads with the same
/// discipline.
pub(crate) struct Reader<'a> {
    pub(crate) buf: &'a [u8],
    pub(crate) pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(Error::Corrupt(format!(
                "truncated: {what} needs {n} bytes at offset {}, only {} \
                 in file",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub(crate) fn u16(&mut self, what: &str) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    pub(crate) fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub(crate) fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub(crate) fn f32_vec(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| {
            Error::Corrupt(format!("{what}: length overflow"))
        })?, what)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u16_vec(&mut self, n: usize, what: &str) -> Result<Vec<u16>> {
        let bytes = self.take(n.checked_mul(2).ok_or_else(|| {
            Error::Corrupt(format!("{what}: length overflow"))
        })?, what)?;
        Ok(bytes
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i8_vec(&mut self, n: usize, what: &str) -> Result<Vec<i8>> {
        let bytes = self.take(n, what)?;
        Ok(bytes.iter().map(|&b| b as i8).collect())
    }
}

/// One decode source for format-v2 tensor segments: scalars come from
/// the payload [`Reader`]; each tensor comes back either as an owned
/// vector (heap decode, `map == None`) or as a [`MapSlice`] view over
/// the backing [`MapFile`] — the same bytes either way, so both paths
/// produce bit-identical models.
struct TensorSrc<'a> {
    r: Reader<'a>,
    /// `(backing map, absolute file offset of the payload start)` when
    /// decoding over a mapped file on a little-endian host.
    map: Option<(&'a Arc<MapFile>, usize)>,
}

impl<'a> TensorSrc<'a> {
    /// Consume the zero filler up to the next [`PAYLOAD_ALIGN`]
    /// boundary. Nonzero filler is rejected — the padding is
    /// CRC-covered here, but the explicit check keeps the contract
    /// that exactly one valid encoding exists for a given model.
    fn pad(&mut self) -> Result<()> {
        let n = self.r.pos.next_multiple_of(PAYLOAD_ALIGN) - self.r.pos;
        let fill = self.r.take(n, "alignment padding")?;
        if fill.iter().any(|&b| b != 0) {
            return Err(Error::Corrupt(
                "nonzero alignment padding inside record payload".into(),
            ));
        }
        Ok(())
    }

    fn u16s(&mut self, n: usize, what: &str) -> Result<TensorData<u16>> {
        match self.map {
            Some((map, base)) => {
                let off = base + self.r.pos;
                self.r.take(
                    n.checked_mul(2).ok_or_else(|| {
                        Error::Corrupt(format!("{what}: length overflow"))
                    })?,
                    what,
                )?;
                Ok(MapSlice::<u16>::new(map, off, n, what)?.into())
            }
            None => Ok(self.r.u16_vec(n, what)?.into()),
        }
    }

    fn i8s(&mut self, n: usize, what: &str) -> Result<TensorData<i8>> {
        match self.map {
            Some((map, base)) => {
                let off = base + self.r.pos;
                self.r.take(n, what)?;
                Ok(MapSlice::<i8>::new(map, off, n, what)?.into())
            }
            None => Ok(self.r.i8_vec(n, what)?.into()),
        }
    }

    fn f32s(&mut self, n: usize, what: &str) -> Result<TensorData<f32>> {
        match self.map {
            Some((map, base)) => {
                let off = base + self.r.pos;
                self.r.take(
                    n.checked_mul(4).ok_or_else(|| {
                        Error::Corrupt(format!("{what}: length overflow"))
                    })?,
                    what,
                )?;
                Ok(MapSlice::<f32>::new(map, off, n, what)?.into())
            }
            None => Ok(self.r.f32_vec(n, what)?.into()),
        }
    }
}

/// Read and validate the fixed file header without touching payloads.
/// Cheap enough for generation polling on the serving path.
pub fn peek_header(bytes: &[u8]) -> Result<ArbfHeader> {
    let mut r = Reader { buf: bytes, pos: 0 };
    let magic = r.take(4, "magic")?;
    if magic != &MAGIC[..] {
        return Err(Error::Corrupt(format!(
            "bad magic {magic:02x?} (expected \"ARBF\")"
        )));
    }
    let version = r.u16("version")?;
    if version != FORMAT_V1 && version != FORMAT_V2 {
        return Err(Error::Corrupt(format!(
            "unsupported format version {version} (this build reads \
             versions {FORMAT_V1} and {FORMAT_V2})"
        )));
    }
    let n_records = r.u16("record count")?;
    if n_records == 0 || n_records > MAX_RECORDS {
        return Err(Error::Corrupt(format!(
            "implausible record count {n_records}"
        )));
    }
    let generation = r.u64("generation")?;
    let dim = r.u32("dim")?;
    let n_sv = r.u32("n_sv")?;
    let flags = r.u64("header flags")?;
    // Unknown bits are ignored (forward compatibility), but the two
    // known quantization bits are mutually exclusive — no encoder
    // writes both, so the combination can only be corruption.
    if flags & FLAG_QUANT_F16 != 0 && flags & FLAG_QUANT_INT8 != 0 {
        return Err(Error::Corrupt(
            "header flags claim both f16 and int8 payloads".into(),
        ));
    }
    // Same reasoning for the random-feature bit: an rff bundle stores
    // its pair in f32, so rff + quantized can only be corruption.
    if flags & FLAG_RFF != 0
        && flags & (FLAG_QUANT_F16 | FLAG_QUANT_INT8) != 0
    {
        return Err(Error::Corrupt(
            "header flags claim both rff and quantized payloads".into(),
        ));
    }
    Ok(ArbfHeader { version, n_records, generation, dim, n_sv, flags })
}

fn decode_policy_payload(payload: &[u8]) -> Result<TenantPolicy> {
    let mut r = Reader { buf: payload, pos: 0 };
    let version = r.u16("policy version")?;
    if version != POLICY_PAYLOAD_VERSION
        && version != POLICY_PAYLOAD_VERSION_DRIFT
    {
        return Err(Error::Corrupt(format!(
            "unsupported policy record version {version} (this build \
             reads versions {POLICY_PAYLOAD_VERSION} and \
             {POLICY_PAYLOAD_VERSION_DRIFT})"
        )));
    }
    let route = match r.u8("policy route")? {
        0 => None,
        1 => Some(RoutePolicy::AlwaysApprox),
        2 => Some(RoutePolicy::AlwaysExact),
        3 => Some(RoutePolicy::Hybrid),
        t => {
            return Err(Error::Corrupt(format!(
                "unknown policy route tag {t}"
            )))
        }
    };
    let max_batch = match r.u32("policy max_batch")? {
        0 => None,
        n => Some(n as usize),
    };
    let max_wait = match r.u64("policy max_wait_us")? {
        0 => None,
        us => Some(Duration::from_micros(us)),
    };
    let max_resident_hint = r.u32("policy max_resident_hint")?;
    let quant_drift_tol = if version == POLICY_PAYLOAD_VERSION_DRIFT {
        let tol = r.f32("policy quant_drift_tol")?;
        if !tol.is_finite() || tol < 0.0 {
            return Err(Error::Corrupt(format!(
                "policy quant_drift_tol {tol} is not finite and >= 0"
            )));
        }
        Some(tol)
    } else {
        None
    };
    if r.pos != payload.len() {
        return Err(Error::Corrupt(format!(
            "policy record: {} trailing payload bytes",
            payload.len() - r.pos
        )));
    }
    Ok(TenantPolicy {
        route,
        max_batch,
        max_wait,
        max_resident_hint,
        quant_drift_tol,
    })
}

fn decode_svm_payload(payload: &[u8], want_dim: u32) -> Result<SvmModel> {
    let mut r = Reader { buf: payload, pos: 0 };
    let tag = r.u8("kernel tag")?;
    let gamma = r.f32("gamma")?;
    let beta = r.f32("coef0")?;
    let b = r.f32("bias")?;
    let n_sv = r.u32("n_sv")? as usize;
    let d = r.u32("dim")? as usize;
    if d != want_dim as usize {
        return Err(Error::Corrupt(format!(
            "svm record dim {d} disagrees with header dim {want_dim}"
        )));
    }
    let kernel = match tag {
        0 => Kernel::Linear,
        1 => Kernel::Rbf { gamma },
        2 => Kernel::Poly2 { gamma, beta },
        t => {
            return Err(Error::Corrupt(format!("unknown kernel tag {t}")))
        }
    };
    check_svm_elems(n_sv, d)?;
    let coef = r.f32_vec(n_sv, "coefficients")?;
    let mut sv = Mat::zeros(n_sv, d);
    for i in 0..n_sv {
        let nnz = r.u32("sv nnz")? as usize;
        if nnz > d {
            return Err(Error::Corrupt(format!(
                "sv {i}: {nnz} nonzeros in dimension {d}"
            )));
        }
        for _ in 0..nnz {
            let idx = r.u32("sv index")? as usize;
            let val = r.f32("sv value")?;
            if idx >= d {
                return Err(Error::Corrupt(format!(
                    "sv {i}: feature index {idx} out of range (d={d})"
                )));
            }
            *sv.at_mut(i, idx) = val;
        }
    }
    if r.pos != payload.len() {
        return Err(Error::Corrupt(format!(
            "svm record: {} trailing payload bytes",
            payload.len() - r.pos
        )));
    }
    let model = SvmModel::new(kernel, sv, coef, b)?;
    model.check_finite().map_err(Error::Corrupt)?;
    Ok(model)
}

fn decode_approx_payload(payload: &[u8], want_dim: u32) -> Result<ApproxModel> {
    let mut r = Reader { buf: payload, pos: 0 };
    let d = r.u32("dim")? as usize;
    if d == 0 {
        return Err(Error::Corrupt("approx record with dim 0".into()));
    }
    if d != want_dim as usize {
        return Err(Error::Corrupt(format!(
            "approx record dim {d} disagrees with header dim {want_dim}"
        )));
    }
    check_approx_elems(d)?;
    let gamma = r.f32("gamma")?;
    let b = r.f32("b")?;
    let c = r.f32("c")?;
    let max_sv_norm_sq = r.f32("max_sv_norm_sq")?;
    let v = r.f32_vec(d, "v")?;
    let upper = r.f32_vec(d * (d + 1) / 2, "M upper triangle")?;
    if r.pos != payload.len() {
        return Err(Error::Corrupt(format!(
            "approx record: {} trailing payload bytes",
            payload.len() - r.pos
        )));
    }
    let mut m = Mat::zeros(d, d);
    let mut k = 0usize;
    for row in 0..d {
        for col in row..d {
            let val = upper[k];
            k += 1;
            *m.at_mut(row, col) = val;
            *m.at_mut(col, row) = val;
        }
    }
    let am = ApproxModel { gamma, b, c, v, m, max_sv_norm_sq };
    am.check_finite().map_err(Error::Corrupt)?;
    Ok(am)
}

/// The alloc-bomb cap applied to every model record, quantized or not:
/// a crafted header must not be able to demand a dense allocation
/// orders of magnitude beyond the payload it ships.
fn check_svm_elems(n_sv: usize, d: usize) -> Result<()> {
    if (n_sv as u64) * (d as u64) > MAX_MODEL_ELEMS {
        return Err(Error::Corrupt(format!(
            "implausible svm record: n_sv={n_sv} × d={d} exceeds the \
             {MAX_MODEL_ELEMS}-element cap"
        )));
    }
    Ok(())
}

fn check_approx_elems(d: usize) -> Result<()> {
    if (d as u64) * (d as u64) > MAX_MODEL_ELEMS {
        return Err(Error::Corrupt(format!(
            "implausible approx record: d={d} demands a {d}×{d} matrix \
             beyond the {MAX_MODEL_ELEMS}-element cap"
        )));
    }
    Ok(())
}

/// Alloc-bomb cap for kind-6 records: the regenerated feature map is a
/// dense `D×d` allocation the payload never ships, so a crafted header
/// could otherwise demand gigabytes from a 28-byte record.
fn check_rff_elems(n_features: usize, d: usize) -> Result<()> {
    if n_features == 0 || d == 0 {
        return Err(Error::Corrupt(format!(
            "rff record needs D ≥ 1 and d ≥ 1 (got D={n_features}, \
             d={d})"
        )));
    }
    if (n_features as u64) * (d as u64) > MAX_MODEL_ELEMS {
        return Err(Error::Corrupt(format!(
            "implausible rff record: D={n_features} × d={d} demands a \
             feature map beyond the {MAX_MODEL_ELEMS}-element cap"
        )));
    }
    Ok(())
}

/// Decode a kind-6 record, regenerating the feature map from the
/// stored seed (so two decodes of the same bytes are bit-identical).
fn decode_rff_payload(payload: &[u8], want_dim: u32) -> Result<RffModel> {
    let mut r = Reader { buf: payload, pos: 0 };
    let d = r.u32("rff dim")? as usize;
    if d != want_dim as usize {
        return Err(Error::Corrupt(format!(
            "rff record dim {d} disagrees with header dim {want_dim}"
        )));
    }
    let n_features = r.u32("rff feature count")? as usize;
    check_rff_elems(n_features, d)?;
    let seed = r.u64("rff seed")?;
    let gamma = r.f32("rff gamma")?;
    let bias = r.f32("rff bias")?;
    let err_est = r.f32("rff err_est")?;
    let w = r.f32_vec(n_features, "rff weights")?;
    if r.pos != payload.len() {
        return Err(Error::Corrupt(format!(
            "rff record: {} trailing payload bytes",
            payload.len() - r.pos
        )));
    }
    RffModel::from_parts(d, seed, gamma, bias, err_est, w)
        .map_err(|e| Error::Corrupt(format!("rff record: {e}")))
}

/// Decode a kind-4 (f16) or kind-5 (int8) record: a role byte, then the
/// quantized twin of the corresponding f32 payload.
fn decode_quant_payload(
    payload: &[u8],
    kind: PayloadKind,
    want_dim: u32,
) -> Result<ModelRecord> {
    let mut r = Reader { buf: payload, pos: 0 };
    let role = r.u8("quant record role")?;
    let rec = match role {
        ROLE_SVM => {
            ModelRecord::QuantSvm(decode_quant_svm(&mut r, kind, want_dim)?)
        }
        ROLE_APPROX => ModelRecord::QuantApprox(decode_quant_approx(
            &mut r, kind, want_dim,
        )?),
        t => {
            return Err(Error::Corrupt(format!(
                "unknown quant record role {t}"
            )))
        }
    };
    if r.pos != payload.len() {
        return Err(Error::Corrupt(format!(
            "quant record: {} trailing payload bytes",
            payload.len() - r.pos
        )));
    }
    Ok(rec)
}

fn decode_quant_svm(
    r: &mut Reader,
    kind: PayloadKind,
    want_dim: u32,
) -> Result<QuantSvmModel> {
    let tag = r.u8("kernel tag")?;
    let gamma = r.f32("gamma")?;
    let beta = r.f32("coef0")?;
    let b = r.f32("bias")?;
    let n_sv = r.u32("n_sv")? as usize;
    let d = r.u32("dim")? as usize;
    if d != want_dim as usize {
        return Err(Error::Corrupt(format!(
            "quant svm record dim {d} disagrees with header dim {want_dim}"
        )));
    }
    let kernel = match tag {
        0 => Kernel::Linear,
        1 => Kernel::Rbf { gamma },
        2 => Kernel::Poly2 { gamma, beta },
        t => {
            return Err(Error::Corrupt(format!("unknown kernel tag {t}")))
        }
    };
    check_svm_elems(n_sv, d)?;
    let coef = match kind {
        PayloadKind::F16 => {
            QuantVec::F16(r.u16_vec(n_sv, "quantized coefficients")?)
        }
        PayloadKind::Int8 => QuantVec::Int8 {
            scale: r.f32("coef scale")?,
            q: r.i8_vec(n_sv, "quantized coefficients")?,
        },
        PayloadKind::F32 => unreachable!("quant decoder"),
    };
    let sv = match kind {
        PayloadKind::F16 => {
            let mut h = vec![0u16; n_sv * d];
            for i in 0..n_sv {
                let nnz = r.u32("sv nnz")? as usize;
                if nnz > d {
                    return Err(Error::Corrupt(format!(
                        "quant sv {i}: {nnz} nonzeros in dimension {d}"
                    )));
                }
                for _ in 0..nnz {
                    let idx = r.u32("sv index")? as usize;
                    let val = r.u16("sv value")?;
                    if idx >= d {
                        return Err(Error::Corrupt(format!(
                            "quant sv {i}: feature index {idx} out of \
                             range (d={d})"
                        )));
                    }
                    h[i * d + idx] = val;
                }
            }
            QuantMat::F16 { rows: n_sv, cols: d, h }
        }
        PayloadKind::Int8 => {
            let mut q = vec![0i8; n_sv * d];
            let mut scales = Vec::with_capacity(n_sv);
            for i in 0..n_sv {
                let nnz = r.u32("sv nnz")? as usize;
                if nnz > d {
                    return Err(Error::Corrupt(format!(
                        "quant sv {i}: {nnz} nonzeros in dimension {d}"
                    )));
                }
                scales.push(r.f32("sv row scale")?);
                for _ in 0..nnz {
                    let idx = r.u32("sv index")? as usize;
                    let val = r.u8("sv value")? as i8;
                    if idx >= d {
                        return Err(Error::Corrupt(format!(
                            "quant sv {i}: feature index {idx} out of \
                             range (d={d})"
                        )));
                    }
                    q[i * d + idx] = val;
                }
            }
            QuantMat::Int8 { rows: n_sv, cols: d, scales, q }
        }
        PayloadKind::F32 => unreachable!("quant decoder"),
    };
    let model = QuantSvmModel { kernel, b, coef, sv };
    model.check().map_err(Error::Corrupt)?;
    Ok(model)
}

fn decode_quant_approx(
    r: &mut Reader,
    kind: PayloadKind,
    want_dim: u32,
) -> Result<QuantApproxModel> {
    let d = r.u32("dim")? as usize;
    if d == 0 {
        return Err(Error::Corrupt("quant approx record with dim 0".into()));
    }
    if d != want_dim as usize {
        return Err(Error::Corrupt(format!(
            "quant approx record dim {d} disagrees with header dim \
             {want_dim}"
        )));
    }
    check_approx_elems(d)?;
    let gamma = r.f32("gamma")?;
    let b = r.f32("b")?;
    let c = r.f32("c")?;
    let max_sv_norm_sq = r.f32("max_sv_norm_sq")?;
    let packed = QuantSymMat::packed_len(d);
    let (v, data) = match kind {
        PayloadKind::F16 => (
            QuantVec::F16(r.u16_vec(d, "quantized v")?),
            QuantSymData::F16(r.u16_vec(packed, "quantized M upper")?),
        ),
        PayloadKind::Int8 => (
            QuantVec::Int8 {
                scale: r.f32("v scale")?,
                q: r.i8_vec(d, "quantized v")?,
            },
            QuantSymData::Int8 {
                scales: r.f32_vec(d, "M row scales")?,
                q: r.i8_vec(packed, "quantized M upper")?,
            },
        ),
        PayloadKind::F32 => unreachable!("quant decoder"),
    };
    let model = QuantApproxModel {
        gamma,
        b,
        c,
        max_sv_norm_sq,
        v,
        m: QuantSymMat { d, data },
    };
    model.check().map_err(Error::Corrupt)?;
    Ok(model)
}

/// Format-v2 twin of [`decode_quant_payload`]: dense, 64-byte-aligned
/// tensor segments instead of v1's sparse rows, sourced through
/// [`TensorSrc`] so the same code serves heap and mapped decodes.
fn decode_quant_payload_v2(
    payload: &[u8],
    kind: PayloadKind,
    want_dim: u32,
    map: Option<(&Arc<MapFile>, usize)>,
) -> Result<ModelRecord> {
    let mut src = TensorSrc { r: Reader { buf: payload, pos: 0 }, map };
    let role = src.r.u8("quant record role")?;
    let rec = match role {
        ROLE_SVM => ModelRecord::QuantSvm(decode_quant_svm_v2(
            &mut src, kind, want_dim,
        )?),
        ROLE_APPROX => ModelRecord::QuantApprox(decode_quant_approx_v2(
            &mut src, kind, want_dim,
        )?),
        t => {
            return Err(Error::Corrupt(format!(
                "unknown quant record role {t}"
            )))
        }
    };
    if src.r.pos != payload.len() {
        return Err(Error::Corrupt(format!(
            "quant record: {} trailing payload bytes",
            payload.len() - src.r.pos
        )));
    }
    Ok(rec)
}

fn decode_quant_svm_v2(
    src: &mut TensorSrc,
    kind: PayloadKind,
    want_dim: u32,
) -> Result<QuantSvmModel> {
    let tag = src.r.u8("kernel tag")?;
    let gamma = src.r.f32("gamma")?;
    let beta = src.r.f32("coef0")?;
    let b = src.r.f32("bias")?;
    let n_sv = src.r.u32("n_sv")? as usize;
    let d = src.r.u32("dim")? as usize;
    if d != want_dim as usize {
        return Err(Error::Corrupt(format!(
            "quant svm record dim {d} disagrees with header dim {want_dim}"
        )));
    }
    let kernel = match tag {
        0 => Kernel::Linear,
        1 => Kernel::Rbf { gamma },
        2 => Kernel::Poly2 { gamma, beta },
        t => {
            return Err(Error::Corrupt(format!("unknown kernel tag {t}")))
        }
    };
    check_svm_elems(n_sv, d)?;
    let coef = match kind {
        PayloadKind::F16 => {
            src.pad()?;
            QuantVec::F16(src.u16s(n_sv, "quantized coefficients")?)
        }
        PayloadKind::Int8 => {
            let scale = src.r.f32("coef scale")?;
            src.pad()?;
            QuantVec::Int8 {
                scale,
                q: src.i8s(n_sv, "quantized coefficients")?,
            }
        }
        PayloadKind::F32 => unreachable!("quant decoder"),
    };
    src.pad()?;
    let sv = match kind {
        PayloadKind::F16 => QuantMat::F16 {
            rows: n_sv,
            cols: d,
            h: src.u16s(n_sv * d, "quantized sv")?,
        },
        PayloadKind::Int8 => {
            let scales = src.f32s(n_sv, "sv row scales")?;
            src.pad()?;
            QuantMat::Int8 {
                rows: n_sv,
                cols: d,
                scales,
                q: src.i8s(n_sv * d, "quantized sv")?,
            }
        }
        PayloadKind::F32 => unreachable!("quant decoder"),
    };
    let model = QuantSvmModel { kernel, b, coef, sv };
    model.check().map_err(Error::Corrupt)?;
    Ok(model)
}

fn decode_quant_approx_v2(
    src: &mut TensorSrc,
    kind: PayloadKind,
    want_dim: u32,
) -> Result<QuantApproxModel> {
    let d = src.r.u32("dim")? as usize;
    if d == 0 {
        return Err(Error::Corrupt("quant approx record with dim 0".into()));
    }
    if d != want_dim as usize {
        return Err(Error::Corrupt(format!(
            "quant approx record dim {d} disagrees with header dim \
             {want_dim}"
        )));
    }
    check_approx_elems(d)?;
    let gamma = src.r.f32("gamma")?;
    let b = src.r.f32("b")?;
    let c = src.r.f32("c")?;
    let max_sv_norm_sq = src.r.f32("max_sv_norm_sq")?;
    let packed = QuantSymMat::packed_len(d);
    let v = match kind {
        PayloadKind::F16 => {
            src.pad()?;
            QuantVec::F16(src.u16s(d, "quantized v")?)
        }
        PayloadKind::Int8 => {
            let scale = src.r.f32("v scale")?;
            src.pad()?;
            QuantVec::Int8 { scale, q: src.i8s(d, "quantized v")? }
        }
        PayloadKind::F32 => unreachable!("quant decoder"),
    };
    src.pad()?;
    let data = match kind {
        PayloadKind::F16 => {
            QuantSymData::F16(src.u16s(packed, "quantized M upper")?)
        }
        PayloadKind::Int8 => {
            let scales = src.f32s(d, "M row scales")?;
            src.pad()?;
            QuantSymData::Int8 {
                scales,
                q: src.i8s(packed, "quantized M upper")?,
            }
        }
        PayloadKind::F32 => unreachable!("quant decoder"),
    };
    let model = QuantApproxModel {
        gamma,
        b,
        c,
        max_sv_norm_sq,
        v,
        m: QuantSymMat { d, data },
    };
    model.check().map_err(Error::Corrupt)?;
    Ok(model)
}

/// Format-v2 twin of [`decode_rff_payload`]: the weight vector comes
/// from the aligned segment after the (unchanged) 28-byte prefix, as
/// a mapped view when a backing map is supplied.
fn decode_rff_payload_v2(
    payload: &[u8],
    want_dim: u32,
    map: Option<(&Arc<MapFile>, usize)>,
) -> Result<RffModel> {
    let mut src = TensorSrc { r: Reader { buf: payload, pos: 0 }, map };
    let d = src.r.u32("rff dim")? as usize;
    if d != want_dim as usize {
        return Err(Error::Corrupt(format!(
            "rff record dim {d} disagrees with header dim {want_dim}"
        )));
    }
    let n_features = src.r.u32("rff feature count")? as usize;
    check_rff_elems(n_features, d)?;
    let seed = src.r.u64("rff seed")?;
    let gamma = src.r.f32("rff gamma")?;
    let bias = src.r.f32("rff bias")?;
    let err_est = src.r.f32("rff err_est")?;
    src.pad()?;
    let w = src.f32s(n_features, "rff weights")?;
    if src.r.pos != payload.len() {
        return Err(Error::Corrupt(format!(
            "rff record: {} trailing payload bytes",
            payload.len() - src.r.pos
        )));
    }
    RffModel::from_parts(d, seed, gamma, bias, err_est, w)
        .map_err(|e| Error::Corrupt(format!("rff record: {e}")))
}

/// One record's framing facts, without decoding its payload.
#[derive(Clone, Copy, Debug)]
pub struct RecordFrame {
    pub kind: u16,
    pub crc32: u32,
    pub payload_len: u64,
    /// Byte offset of the payload within the file.
    pub payload_offset: usize,
    /// Zero-filled pad bytes between the record header and the
    /// payload. Always 0 in format v1 (the header word is reserved
    /// there and ignored on read).
    pub pad: u16,
}

/// Walk the record frames of a file (header + framing validation only;
/// payloads are not parsed). Powers `inspect --arbf` footprint
/// reporting and the format-conformance corpus's CRC re-checks.
pub fn record_frames(bytes: &[u8]) -> Result<Vec<RecordFrame>> {
    let hdr = peek_header(bytes)?;
    let v2 = hdr.version == FORMAT_V2;
    let mut r = Reader { buf: bytes, pos: FILE_HEADER_LEN };
    let mut out = Vec::with_capacity(hdr.n_records as usize);
    for i in 0..hdr.n_records {
        let kind = r.u16("record kind")?;
        let reserved = r.u16("record pad")?;
        let crc = r.u32("record crc")?;
        let len = r.u64("record payload length")?;
        let pad = check_record_pad(v2, i, reserved, r.pos)?;
        let _ = r.take(pad as usize, "record padding")?;
        let avail = (r.buf.len() - r.pos) as u64;
        if len > avail {
            return Err(Error::Corrupt(format!(
                "record {i}: payload length {len} exceeds remaining file \
                 size {avail}"
            )));
        }
        let payload_offset = r.pos;
        let _ = r.take(len as usize, "record payload")?;
        out.push(RecordFrame {
            kind,
            crc32: crc,
            payload_len: len,
            payload_offset,
            pad,
        });
    }
    if r.pos != bytes.len() {
        return Err(Error::Corrupt(format!(
            "{} trailing bytes after final record",
            bytes.len() - r.pos
        )));
    }
    Ok(out)
}

/// Validate a record header's pad word. In v1 the word is reserved —
/// ignored entirely, so pre-existing files keep decoding — and the
/// effective pad is 0. In v2 it must place the payload on the next
/// [`PAYLOAD_ALIGN`] boundary; `header_end` is the file offset just
/// after the 16-byte record header.
fn check_record_pad(
    v2: bool,
    record: u16,
    reserved: u16,
    header_end: usize,
) -> Result<u16> {
    if !v2 {
        return Ok(0);
    }
    let expect = header_end.next_multiple_of(PAYLOAD_ALIGN) - header_end;
    if reserved as usize != expect {
        return Err(Error::Corrupt(format!(
            "record {record}: pad {reserved} does not place the payload \
             on a {PAYLOAD_ALIGN}-byte boundary (expected {expect})"
        )));
    }
    Ok(reserved)
}

/// The cheaply-peekable facts of a kind-6 record: what `registry list`
/// and `inspect --arbf` render without decoding the weight vector or
/// regenerating the feature map.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RffSummary {
    pub n_features: u32,
    pub seed: u64,
    pub gamma: f32,
    /// Stored Monte-Carlo decision-error estimate.
    pub err_est: f32,
}

/// Scan the record frames for a kind-6 record and read its fixed
/// 28-byte prefix. `Ok(None)` when the file holds no rff record.
pub fn peek_rff_summary(bytes: &[u8]) -> Result<Option<RffSummary>> {
    for frame in record_frames(bytes)? {
        if frame.kind != KIND_RFF {
            continue;
        }
        let start = frame.payload_offset;
        let end = start + frame.payload_len as usize;
        let mut r = Reader { buf: &bytes[start..end], pos: 0 };
        let _dim = r.u32("rff dim")?;
        let n_features = r.u32("rff feature count")?;
        let seed = r.u64("rff seed")?;
        let gamma = r.f32("rff gamma")?;
        let _bias = r.f32("rff bias")?;
        let err_est = r.f32("rff err_est")?;
        return Ok(Some(RffSummary { n_features, seed, gamma, err_est }));
    }
    Ok(None)
}

/// Decode a whole `.arbf` file into its records, verifying framing and
/// per-record CRCs. Always decodes to the heap; mapped serving goes
/// through [`decode_bundle_mapped`].
pub fn decode(bytes: &[u8]) -> Result<(ArbfHeader, Vec<ModelRecord>)> {
    decode_records(bytes, None)
}

/// Walk and decode every record. `map` supplies the mmap backing for
/// format-v2 tensor views; `None` (or a v1 file) decodes to the heap.
/// Every payload is CRC-verified before any view is handed out.
fn decode_records(
    bytes: &[u8],
    map: Option<&Arc<MapFile>>,
) -> Result<(ArbfHeader, Vec<ModelRecord>)> {
    let hdr = peek_header(bytes)?;
    let v2 = hdr.version == FORMAT_V2;
    let mut r = Reader { buf: bytes, pos: FILE_HEADER_LEN };
    let mut records = Vec::with_capacity(hdr.n_records as usize);
    for i in 0..hdr.n_records {
        let kind = r.u16("record kind")?;
        let reserved = r.u16("record pad")?;
        let want_crc = r.u32("record crc")?;
        let len = r.u64("record payload length")?;
        let pad = check_record_pad(v2, i, reserved, r.pos)?;
        // The pad bytes precede the payload, so the record CRC does
        // not cover them: the zero check here is the only thing
        // standing between filler tampering and silent acceptance.
        let fill = r.take(pad as usize, "record padding")?;
        if fill.iter().any(|&b| b != 0) {
            return Err(Error::Corrupt(format!(
                "record {i}: nonzero padding before payload"
            )));
        }
        let avail = (r.buf.len() - r.pos) as u64;
        if len > avail {
            return Err(Error::Corrupt(format!(
                "record {i}: payload length {len} exceeds remaining file \
                 size {avail}"
            )));
        }
        let payload_offset = r.pos;
        let payload = r.take(len as usize, "record payload")?;
        let got_crc = crc32(payload);
        if got_crc != want_crc {
            return Err(Error::Corrupt(format!(
                "record {i}: CRC-32 mismatch (stored {want_crc:#010x}, \
                 computed {got_crc:#010x})"
            )));
        }
        let src_map = map.map(|m| (m, payload_offset));
        records.push(match kind {
            KIND_SVM => ModelRecord::Svm(decode_svm_payload(payload, hdr.dim)?),
            KIND_APPROX => {
                ModelRecord::Approx(decode_approx_payload(payload, hdr.dim)?)
            }
            KIND_POLICY => {
                ModelRecord::Policy(decode_policy_payload(payload)?)
            }
            KIND_QUANT_F16 if v2 => decode_quant_payload_v2(
                payload,
                PayloadKind::F16,
                hdr.dim,
                src_map,
            )?,
            KIND_QUANT_INT8 if v2 => decode_quant_payload_v2(
                payload,
                PayloadKind::Int8,
                hdr.dim,
                src_map,
            )?,
            KIND_QUANT_F16 => {
                decode_quant_payload(payload, PayloadKind::F16, hdr.dim)?
            }
            KIND_QUANT_INT8 => {
                decode_quant_payload(payload, PayloadKind::Int8, hdr.dim)?
            }
            KIND_RFF if v2 => ModelRecord::Rff(decode_rff_payload_v2(
                payload, hdr.dim, src_map,
            )?),
            KIND_RFF => {
                ModelRecord::Rff(decode_rff_payload(payload, hdr.dim)?)
            }
            k => {
                return Err(Error::Corrupt(format!(
                    "record {i}: unknown kind {k}"
                )))
            }
        });
    }
    if r.pos != bytes.len() {
        return Err(Error::Corrupt(format!(
            "{} trailing bytes after final record",
            bytes.len() - r.pos
        )));
    }
    Ok((hdr, records))
}

/// Decode a standalone exact-model file.
pub fn decode_svm(bytes: &[u8]) -> Result<SvmModel> {
    match decode(bytes)?.1.into_iter().next() {
        Some(ModelRecord::Svm(m)) => Ok(m),
        _ => Err(Error::Corrupt("expected a single svm record".into())),
    }
}

/// Decode a standalone approx-model file.
pub fn decode_approx(bytes: &[u8]) -> Result<ApproxModel> {
    match decode(bytes)?.1.into_iter().next() {
        Some(ModelRecord::Approx(m)) => Ok(m),
        _ => Err(Error::Corrupt("expected a single approx record".into())),
    }
}

/// Decode a registry bundle including its optional policy record, in
/// whatever payload precision it was written with. The header's
/// payload flags must agree with the actual record kinds, the bundle
/// must hold exactly one exact and one approx model record, and a
/// quantized bundle's records must share one precision.
pub fn decode_bundle_full(bytes: &[u8]) -> Result<Bundle> {
    let (hdr, records) = decode(bytes)?;
    assemble_bundle(hdr, records)
}

///// Decode a bundle over its memory-mapped backing: format-v2 tensor
/// payloads become borrowed views into `map` (each view holds its own
/// `Arc` clone, so the mapping outlives the store entry that loaded
/// it), while v1 files — and big-endian hosts, where the little-endian
/// wire layout cannot be reinterpreted in place — fall back to a plain
/// heap decode of the mapped bytes. Every payload is CRC-verified
/// either way.
pub fn decode_bundle_mapped(map: &Arc<MapFile>) -> Result<Bundle> {
    let src = if cfg!(target_endian = "little") {
        Some(map)
    } else {
        None
    };
    let (hdr, records) = decode_records(map.bytes(), src)?;
    assemble_bundle(hdr, records)
}

fn assemble_bundle(
    hdr: ArbfHeader,
    records: Vec<ModelRecord>,
) -> Result<Bundle> {
    let mut exact = None;
    let mut approx = None;
    let mut q_exact: Option<QuantSvmModel> = None;
    let mut q_approx: Option<QuantApproxModel> = None;
    let mut rff: Option<RffModel> = None;
    let mut policy = None;
    for rec in records {
        match rec {
            ModelRecord::Svm(m) if exact.is_none() => exact = Some(m),
            ModelRecord::Approx(a) if approx.is_none() => approx = Some(a),
            ModelRecord::QuantSvm(m) if q_exact.is_none() => {
                q_exact = Some(m)
            }
            ModelRecord::QuantApprox(a) if q_approx.is_none() => {
                q_approx = Some(a)
            }
            ModelRecord::Rff(m) if rff.is_none() => rff = Some(m),
            ModelRecord::Policy(p) if policy.is_none() => policy = Some(p),
            _ => {
                return Err(Error::Corrupt(
                    "bundle holds a duplicate record kind".into(),
                ))
            }
        }
    }
    let models = match (exact, approx, q_exact, q_approx, rff) {
        (Some(exact), Some(approx), None, None, None) => {
            TenantModels::F32 { exact, approx }
        }
        // Record-level dims already agree: every model record
        // cross-checked its own dim against the header's.
        (Some(exact), Some(approx), None, None, Some(rff)) => {
            TenantModels::Rff { exact, approx, rff }
        }
        (None, None, Some(exact), Some(approx), None) => {
            if exact.payload() != approx.payload() {
                return Err(Error::Corrupt(format!(
                    "bundle mixes payload kinds ({} exact vs {} approx)",
                    exact.payload(),
                    approx.payload()
                )));
            }
            TenantModels::Quantized { exact, approx }
        }
        _ => {
            return Err(Error::Corrupt(
                "bundle must hold one exact record and one approx record \
                 of a single payload kind"
                    .into(),
            ))
        }
    };
    if models.payload() != hdr.payload() {
        return Err(Error::Corrupt(format!(
            "header advertises {} payloads but records are {}",
            hdr.payload(),
            models.payload()
        )));
    }
    let is_rff = matches!(models, TenantModels::Rff { .. });
    if hdr.has_rff() != is_rff {
        return Err(Error::Corrupt(format!(
            "header advertises rff={} but the bundle {} a kind-6 record",
            hdr.has_rff(),
            if is_rff { "holds" } else { "lacks" }
        )));
    }
    Ok(Bundle {
        generation: hdr.generation,
        format: hdr.format(),
        models,
        policy,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_approx() -> ApproxModel {
        ApproxModel {
            gamma: 0.1,
            b: -0.2,
            c: 0.5,
            v: vec![1.0, -2.0, 0.25],
            m: Mat::from_vec(
                3,
                3,
                vec![0.5, 0.25, -1.0, 0.25, -0.75, 2.0, -1.0, 2.0, 0.125],
            )
            .unwrap(),
            max_sv_norm_sq: 4.0,
        }
    }

    fn toy_svm() -> SvmModel {
        SvmModel::new(
            Kernel::Rbf { gamma: 0.25 },
            Mat::from_vec(3, 3, vec![1., 0., 2., 0., 2., 0., -1., 1., 0.5])
                .unwrap(),
            vec![0.5, -1.0, 0.75],
            0.1,
        )
        .unwrap()
    }

    #[test]
    fn approx_binary_roundtrip_exact_bits() {
        let am = toy_approx();
        let bytes = encode_approx(&am).unwrap();
        let back = decode_approx(&bytes).unwrap();
        assert_eq!(back.v, am.v);
        assert_eq!(back.m.max_abs_diff(&am.m), 0.0);
        assert_eq!(back.gamma, am.gamma);
        assert_eq!(back.b, am.b);
        assert_eq!(back.c, am.c);
        assert_eq!(back.max_sv_norm_sq, am.max_sv_norm_sq);
        // Binary beats the text codec on size for this model.
        assert!(bytes.len() < am.to_text().len());
    }

    #[test]
    fn svm_binary_roundtrip_preserves_sparsity_and_dim() {
        let m = toy_svm();
        let bytes = encode_svm(&m).unwrap();
        let back = decode_svm(&bytes).unwrap();
        assert_eq!(back.coef, m.coef);
        assert_eq!(back.sv.max_abs_diff(&m.sv), 0.0);
        assert_eq!(back.kernel, m.kernel);
        assert_eq!(back.b, m.b);
        // Unlike the text codec, binary keeps explicit d even when the
        // last column is all-zero.
        assert_eq!(back.dim(), 3);
    }

    #[test]
    fn bundle_roundtrip_carries_generation() {
        let e = toy_svm();
        let a = toy_approx();
        let bytes = encode_bundle(7, &e, &a).unwrap();
        let hdr = peek_header(&bytes).unwrap();
        assert_eq!(hdr.generation, 7);
        assert_eq!(hdr.n_records, 2);
        assert_eq!(hdr.dim, 3);
        assert_eq!(hdr.n_sv, 3);
        let b = decode_bundle_full(&bytes).unwrap();
        assert_eq!(b.generation, 7);
        assert_eq!(b.payload(), PayloadKind::F32);
        assert_eq!(b.exact_dequant().n_sv(), e.n_sv());
        assert_eq!(b.approx_dequant().v, a.v);
        assert_eq!(b.policy, None);
    }

    #[test]
    fn bad_magic_is_corrupt() {
        let mut bytes = encode_approx(&toy_approx()).unwrap();
        bytes[0] = b'X';
        assert!(matches!(
            decode_approx(&bytes),
            Err(Error::Corrupt(m)) if m.contains("magic")
        ));
    }

    #[test]
    fn wrong_version_is_corrupt() {
        let mut bytes = encode_approx(&toy_approx()).unwrap();
        bytes[4] = 99;
        assert!(matches!(
            decode_approx(&bytes),
            Err(Error::Corrupt(m)) if m.contains("version")
        ));
    }

    #[test]
    fn payload_bitflip_fails_crc() {
        let mut bytes = encode_bundle(1, &toy_svm(), &toy_approx()).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0x40;
        assert!(matches!(
            decode_bundle_full(&bytes),
            Err(Error::Corrupt(m)) if m.contains("CRC-32")
        ));
    }

    #[test]
    fn truncation_is_typed_not_panic() {
        let bytes = encode_bundle(1, &toy_svm(), &toy_approx()).unwrap();
        for cut in [0, 3, FILE_HEADER_LEN - 1, FILE_HEADER_LEN + 5, bytes.len() - 1]
        {
            let err = decode_bundle_full(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, Error::Corrupt(_)),
                "cut at {cut}: {err}"
            );
        }
    }

    #[test]
    fn bundle_policy_record_roundtrips_and_sets_flag() {
        let e = toy_svm();
        let a = toy_approx();
        let policy = TenantPolicy {
            route: Some(RoutePolicy::AlwaysExact),
            max_batch: Some(32),
            max_wait: Some(Duration::from_micros(750)),
            max_resident_hint: 5,
            quant_drift_tol: None,
        };
        let bytes = encode_bundle_with(3, &e, &a, Some(&policy)).unwrap();
        let hdr = peek_header(&bytes).unwrap();
        assert!(hdr.has_policy());
        assert_eq!(hdr.n_records, 3);
        let b = decode_bundle_full(&bytes).unwrap();
        assert_eq!(b.generation, 3);
        assert_eq!(b.policy, Some(policy));
        assert_eq!(b.exact_dequant().n_sv(), e.n_sv());
    }

    #[test]
    fn policy_drift_tol_writes_v2_record_and_roundtrips() {
        let e = toy_svm();
        let a = toy_approx();
        // A set tolerance promotes the record to the 23-byte v2 body…
        let with_tol = TenantPolicy {
            quant_drift_tol: Some(0.0625),
            ..Default::default()
        };
        let bytes = encode_bundle_with(1, &e, &a, Some(&with_tol)).unwrap();
        let frames = record_frames(&bytes).unwrap();
        let policy_frame = frames.last().unwrap();
        assert_eq!(policy_frame.kind, KIND_POLICY);
        assert_eq!(policy_frame.payload_len, 23);
        let b = decode_bundle_full(&bytes).unwrap();
        assert_eq!(b.policy, Some(with_tol));
        // …while an unset tolerance keeps the original v1 body, so
        // pre-existing bundles stay byte-stable.
        let without = TenantPolicy {
            max_batch: Some(4),
            ..Default::default()
        };
        let bytes = encode_bundle_with(1, &e, &a, Some(&without)).unwrap();
        let frames = record_frames(&bytes).unwrap();
        assert_eq!(frames.last().unwrap().payload_len, 19);
        assert_eq!(
            decode_bundle_full(&bytes).unwrap().policy,
            Some(without)
        );
        // A zero tolerance is meaningful ("escort everything exact")
        // and must survive, not collapse to unset.
        let zero = TenantPolicy {
            quant_drift_tol: Some(0.0),
            ..Default::default()
        };
        let bytes = encode_bundle_with(1, &e, &a, Some(&zero)).unwrap();
        assert_eq!(
            decode_bundle_full(&bytes).unwrap().policy,
            Some(zero)
        );
    }

    #[test]
    fn policy_drift_tol_rejects_non_finite_and_negative() {
        let e = toy_svm();
        let a = toy_approx();
        for bad in [f32::NAN, f32::INFINITY, -0.5] {
            let p = TenantPolicy {
                quant_drift_tol: Some(bad),
                ..Default::default()
            };
            assert!(
                matches!(
                    encode_bundle_with(1, &e, &a, Some(&p)),
                    Err(Error::InvalidArg(_))
                ),
                "tol {bad} must be refused on encode"
            );
        }
        // A corrupted v2 record whose trailing f32 is negative decodes
        // as Corrupt, not as a policy.
        let good = encode_bundle_with(
            1,
            &e,
            &a,
            Some(&TenantPolicy {
                quant_drift_tol: Some(0.5),
                ..Default::default()
            }),
        )
        .unwrap();
        let pstart = good.len() - 23;
        let mut bad = good;
        bad[pstart + 19..pstart + 23]
            .copy_from_slice(&(-1.0f32).to_le_bytes());
        let crc = crc32(&bad[pstart..]);
        bad[pstart - 12..pstart - 8].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            decode_bundle_full(&bad),
            Err(Error::Corrupt(m)) if m.contains("quant_drift_tol")
        ));
    }

    #[test]
    fn bundle_without_policy_has_no_flag() {
        let bytes = encode_bundle(1, &toy_svm(), &toy_approx()).unwrap();
        let hdr = peek_header(&bytes).unwrap();
        assert!(!hdr.has_policy());
        assert_eq!(hdr.flags, 0);
        assert_eq!(decode_bundle_full(&bytes).unwrap().policy, None);
    }

    #[test]
    fn policy_record_bad_version_and_route_are_corrupt() {
        let policy = TenantPolicy::default();
        let e = toy_svm();
        let a = toy_approx();
        let good = encode_bundle_with(1, &e, &a, Some(&policy)).unwrap();
        // The policy record is the last one; its payload starts 16
        // bytes before the end minus payload length (19 bytes).
        let plen = 19;
        let pstart = good.len() - plen;
        // Bad payload version.
        let mut bad = good.clone();
        bad[pstart] = 9;
        // Re-stamp the CRC so the corruption reaches the payload parser.
        let crc = crc32(&bad[pstart..]);
        bad[pstart - 12..pstart - 8].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            decode_bundle_full(&bad),
            Err(Error::Corrupt(m)) if m.contains("policy record version")
        ));
        // Bad route tag.
        let mut bad = good;
        bad[pstart + 2] = 7;
        let crc = crc32(&bad[pstart..]);
        bad[pstart - 12..pstart - 8].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            decode_bundle_full(&bad),
            Err(Error::Corrupt(m)) if m.contains("route tag")
        ));
    }

    #[test]
    fn non_finite_rejected_on_encode() {
        let mut am = toy_approx();
        am.gamma = f32::NAN;
        assert!(matches!(
            encode_approx(&am),
            Err(Error::InvalidArg(_))
        ));
        let mut sv = toy_svm();
        sv.coef[1] = f32::INFINITY;
        assert!(matches!(encode_svm(&sv), Err(Error::InvalidArg(_))));
    }

    // -- kind-4/5 quantized records -----------------------------------

    #[test]
    fn quantized_bundle_roundtrips_natively_and_sets_flags() {
        let e = toy_svm();
        let a = toy_approx();
        for (kind, flag, code) in [
            (PayloadKind::F16, FLAG_QUANT_F16, KIND_QUANT_F16),
            (PayloadKind::Int8, FLAG_QUANT_INT8, KIND_QUANT_INT8),
        ] {
            let bytes =
                encode_bundle_quantized(5, &e, &a, None, kind).unwrap();
            let hdr = peek_header(&bytes).unwrap();
            assert_eq!(hdr.payload(), kind);
            assert_eq!(hdr.flags, flag);
            assert_eq!(hdr.n_records, 2);
            assert_eq!(hdr.dim, 3);
            assert_eq!(hdr.n_sv, 3);
            let frames = record_frames(&bytes).unwrap();
            assert!(frames.iter().all(|f| f.kind == code));
            let b = decode_bundle_full(&bytes).unwrap();
            assert_eq!(b.generation, 5);
            assert_eq!(b.payload(), kind);
            // Lossless native re-encode: the byte-stability contract
            // rollback and the golden corpus rely on.
            let again =
                encode_bundle_native(5, &b.models, b.policy.as_ref())
                    .unwrap();
            assert_eq!(again, bytes, "{kind}: native re-encode drifted");
            // Dequantized models stay within the advertised bounds.
            let deq = b.approx_dequant();
            assert_eq!(deq.dim(), 3);
            let err = b.models.quant_error().unwrap();
            for r in 0..3 {
                for c in 0..3 {
                    assert!(
                        (deq.m.at(r, c) - a.m.at(r, c)).abs() <= err.eps_m,
                        "{kind} M[{r}][{c}]"
                    );
                    assert_eq!(deq.m.at(r, c), deq.m.at(c, r));
                }
            }
        }
    }

    #[test]
    fn quantized_bundle_carries_policy() {
        let policy = TenantPolicy {
            route: Some(RoutePolicy::Hybrid),
            max_batch: Some(8),
            max_wait: Some(Duration::from_micros(100)),
            max_resident_hint: 1,
            quant_drift_tol: Some(0.125),
        };
        let bytes = encode_bundle_quantized(
            2,
            &toy_svm(),
            &toy_approx(),
            Some(&policy),
            PayloadKind::Int8,
        )
        .unwrap();
        let hdr = peek_header(&bytes).unwrap();
        assert!(hdr.has_policy());
        assert_eq!(hdr.payload(), PayloadKind::Int8);
        assert_eq!(hdr.flags, FLAG_HAS_POLICY | FLAG_QUANT_INT8);
        let b = decode_bundle_full(&bytes).unwrap();
        assert_eq!(b.policy, Some(policy));
    }

    #[test]
    fn quantized_record_bitflip_fails_crc() {
        let bytes = encode_bundle_quantized(
            1,
            &toy_svm(),
            &toy_approx(),
            None,
            PayloadKind::Int8,
        )
        .unwrap();
        let mut bad = bytes.clone();
        let n = bad.len();
        bad[n - 2] ^= 0x10;
        assert!(matches!(
            decode_bundle_full(&bad),
            Err(Error::Corrupt(m)) if m.contains("CRC-32")
        ));
        // Truncation at every prefix length stays typed — never panics.
        for cut in 0..bytes.len() {
            assert!(matches!(
                decode_bundle_full(&bytes[..cut]),
                Err(Error::Corrupt(_))
            ));
        }
    }

    #[test]
    fn contradictory_quant_flags_are_corrupt_at_peek() {
        let mut bytes = encode_bundle_quantized(
            1,
            &toy_svm(),
            &toy_approx(),
            None,
            PayloadKind::F16,
        )
        .unwrap();
        bytes[24] |= FLAG_QUANT_INT8 as u8; // f16 | int8: impossible
        assert!(matches!(
            peek_header(&bytes),
            Err(Error::Corrupt(m)) if m.contains("both f16 and int8")
        ));
        assert!(decode_bundle_full(&bytes).is_err());
    }

    #[test]
    fn quant_payload_flag_mismatch_is_corrupt() {
        // Flip the quantization flag off: records say int8, header
        // says f32 → the cross-check must refuse.
        let mut bytes = encode_bundle_quantized(
            1,
            &toy_svm(),
            &toy_approx(),
            None,
            PayloadKind::Int8,
        )
        .unwrap();
        bytes[24] &= !(FLAG_QUANT_INT8 as u8);
        assert!(matches!(
            decode_bundle_full(&bytes),
            Err(Error::Corrupt(m)) if m.contains("advertises")
        ));
    }

    #[test]
    fn oversized_quant_header_claims_are_capped() {
        // Craft a kind-5 record whose header claims a huge n_sv×d: the
        // alloc-bomb cap must reject it before any allocation.
        let bytes = encode_bundle_quantized(
            1,
            &toy_svm(),
            &toy_approx(),
            None,
            PayloadKind::Int8,
        )
        .unwrap();
        let frames = record_frames(&bytes).unwrap();
        let svm = frames[0];
        let mut bad = bytes.clone();
        // Payload layout: role(1) + tag(1) + 3×f32(12) + n_sv(4) + d(4).
        let n_sv_off = svm.payload_offset + 14;
        bad[n_sv_off..n_sv_off + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        let start = svm.payload_offset;
        let end = start + svm.payload_len as usize;
        let crc = crc32(&bad[start..end]);
        bad[start - 12..start - 8].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            decode_bundle_full(&bad),
            Err(Error::Corrupt(m)) if m.contains("element cap")
        ));
    }

    #[test]
    fn mixed_or_missing_quant_records_are_corrupt() {
        // Hand-assemble a bundle holding two approx-role records and no
        // svm — structurally framed correctly, semantically invalid.
        let a = toy_approx();
        let qa =
            QuantApproxModel::quantize(&a, PayloadKind::Int8).unwrap();
        let payload = quant_approx_payload(&qa);
        let bytes = write_file(
            FormatVersion::V1,
            1,
            a.dim(),
            0,
            FLAG_QUANT_INT8,
            vec![
                (KIND_QUANT_INT8, payload.clone()),
                (KIND_QUANT_INT8, payload),
            ],
        );
        assert!(matches!(
            decode_bundle_full(&bytes),
            Err(Error::Corrupt(_))
        ));
    }

    // -- kind-6 random-feature records --------------------------------

    fn toy_rff() -> RffModel {
        RffModel::fit(&toy_svm(), Some(64), 42).unwrap()
    }

    #[test]
    fn rff_bundle_roundtrips_and_sets_flag() {
        let e = toy_svm();
        let a = toy_approx();
        let rff = toy_rff();
        let bytes = encode_bundle_rff(9, &e, &a, &rff, None).unwrap();
        let hdr = peek_header(&bytes).unwrap();
        assert_eq!(hdr.flags, FLAG_RFF);
        assert!(hdr.has_rff());
        assert_eq!(hdr.n_records, 3);
        assert_eq!(hdr.payload(), PayloadKind::F32);
        let frames = record_frames(&bytes).unwrap();
        assert_eq!(
            frames.iter().map(|f| f.kind).collect::<Vec<_>>(),
            vec![KIND_SVM, KIND_APPROX, KIND_RFF]
        );
        assert_eq!(frames[2].payload_len, 28 + 4 * 64u64);
        let b = decode_bundle_full(&bytes).unwrap();
        assert_eq!(b.generation, 9);
        let TenantModels::Rff { rff: back, .. } = &b.models else {
            panic!("expected an rff bundle, got {:?}", b.models.payload());
        };
        assert_eq!(back.seed, rff.seed);
        assert_eq!(back.w, rff.w);
        assert_eq!(back.err_est, rff.err_est);
        // The regenerated map gives bit-identical decisions.
        let z = [0.4f32, -0.2, 1.0];
        assert_eq!(
            back.decision_one(&z).0.to_bits(),
            rff.decision_one(&z).0.to_bits()
        );
        // Byte-stability: native re-encode reproduces the file exactly.
        let again =
            encode_bundle_native(9, &b.models, b.policy.as_ref()).unwrap();
        assert_eq!(again, bytes);
        // Cheap introspection sees the stored facts.
        let s = peek_rff_summary(&bytes).unwrap().unwrap();
        assert_eq!(s.n_features, 64);
        assert_eq!(s.seed, rff.seed);
        assert_eq!(s.err_est, rff.err_est);
        // Non-rff files peek as None.
        let plain = encode_bundle(1, &e, &a).unwrap();
        assert_eq!(peek_rff_summary(&plain).unwrap(), None);
    }

    #[test]
    fn rff_bundle_carries_policy() {
        let policy = TenantPolicy {
            route: Some(RoutePolicy::Hybrid),
            quant_drift_tol: Some(0.5),
            ..Default::default()
        };
        let bytes = encode_bundle_rff(
            2,
            &toy_svm(),
            &toy_approx(),
            &toy_rff(),
            Some(&policy),
        )
        .unwrap();
        let hdr = peek_header(&bytes).unwrap();
        assert_eq!(hdr.flags, FLAG_RFF | FLAG_HAS_POLICY);
        let b = decode_bundle_full(&bytes).unwrap();
        assert_eq!(b.policy, Some(policy));
    }

    #[test]
    fn rff_flag_mismatch_is_corrupt() {
        // Clear FLAG_RFF: records hold a kind-6, header denies it.
        let mut bytes =
            encode_bundle_rff(1, &toy_svm(), &toy_approx(), &toy_rff(), None)
                .unwrap();
        bytes[24] &= !(FLAG_RFF as u8);
        assert!(matches!(
            decode_bundle_full(&bytes),
            Err(Error::Corrupt(m)) if m.contains("advertises")
        ));
        // Set FLAG_RFF on a plain bundle: header promises a kind-6 the
        // records lack.
        let mut bytes = encode_bundle(1, &toy_svm(), &toy_approx()).unwrap();
        bytes[24] |= FLAG_RFF as u8;
        assert!(matches!(
            decode_bundle_full(&bytes),
            Err(Error::Corrupt(m)) if m.contains("advertises")
        ));
    }

    #[test]
    fn contradictory_rff_and_quant_flags_are_corrupt_at_peek() {
        let mut bytes =
            encode_bundle_rff(1, &toy_svm(), &toy_approx(), &toy_rff(), None)
                .unwrap();
        bytes[24] |= FLAG_QUANT_INT8 as u8;
        assert!(matches!(
            peek_header(&bytes),
            Err(Error::Corrupt(m)) if m.contains("rff and quantized")
        ));
    }

    #[test]
    fn oversized_rff_feature_claims_are_capped() {
        // Inflate the stored D: the alloc-bomb cap must reject before
        // the D×d map regeneration allocates anything.
        let bytes =
            encode_bundle_rff(1, &toy_svm(), &toy_approx(), &toy_rff(), None)
                .unwrap();
        let frames = record_frames(&bytes).unwrap();
        let rff_frame = frames[2];
        let mut bad = bytes.clone();
        let d_feat_off = rff_frame.payload_offset + 4;
        bad[d_feat_off..d_feat_off + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        let start = rff_frame.payload_offset;
        let end = start + rff_frame.payload_len as usize;
        let crc = crc32(&bad[start..end]);
        bad[start - 12..start - 8].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            decode_bundle_full(&bad),
            Err(Error::Corrupt(m)) if m.contains("element cap")
        ));
    }

    #[test]
    fn rff_record_bitflip_fails_crc_and_truncation_is_typed() {
        let bytes =
            encode_bundle_rff(1, &toy_svm(), &toy_approx(), &toy_rff(), None)
                .unwrap();
        let mut bad = bytes.clone();
        let n = bad.len();
        bad[n - 5] ^= 0x20;
        assert!(matches!(
            decode_bundle_full(&bad),
            Err(Error::Corrupt(m)) if m.contains("CRC-32")
        ));
        for cut in 0..bytes.len() {
            assert!(matches!(
                decode_bundle_full(&bytes[..cut]),
                Err(Error::Corrupt(_))
            ));
        }
    }

    #[test]
    fn f16_overflow_rejected_at_quantized_encode() {
        let mut a = toy_approx();
        a.v[0] = 1.0e5; // beyond f16 range
        let err = encode_bundle_quantized(
            1,
            &toy_svm(),
            &a,
            None,
            PayloadKind::F16,
        )
        .unwrap_err();
        assert!(matches!(err, Error::InvalidArg(m) if m.contains("f16")));
        // …but int8 takes it fine.
        assert!(encode_bundle_quantized(
            1,
            &toy_svm(),
            &a,
            None,
            PayloadKind::Int8
        )
        .is_ok());
    }

    // -- format v2 -----------------------------------------------------

    #[test]
    fn format_version_parses_displays_and_pins_alignment() {
        assert_eq!("v1".parse::<FormatVersion>().unwrap(), FormatVersion::V1);
        assert_eq!("V2".parse::<FormatVersion>().unwrap(), FormatVersion::V2);
        assert_eq!("2".parse::<FormatVersion>().unwrap(), FormatVersion::V2);
        assert!("v3".parse::<FormatVersion>().is_err());
        assert!("".parse::<FormatVersion>().is_err());
        assert_eq!(FormatVersion::V1.to_string(), "v1");
        assert_eq!(FormatVersion::V2.to_string(), "v2");
        assert_eq!(FormatVersion::default(), FormatVersion::V1);
        assert_eq!(FormatVersion::V1.number(), FORMAT_V1);
        assert_eq!(FormatVersion::V2.number(), FORMAT_V2);
        assert_eq!(VERSION, FORMAT_V1);
        // The committed alignment and the mapfile substrate agree.
        assert_eq!(PAYLOAD_ALIGN, crate::registry::mapfile::PAYLOAD_ALIGN);
    }

    #[test]
    fn v2_payloads_start_on_aligned_offsets() {
        let e = toy_svm();
        let a = toy_approx();
        let bundles = [
            encode_bundle_quantized_at(
                3, &e, &a, None, PayloadKind::F32, FormatVersion::V2,
            )
            .unwrap(),
            encode_bundle_quantized_at(
                3, &e, &a, None, PayloadKind::F16, FormatVersion::V2,
            )
            .unwrap(),
            encode_bundle_quantized_at(
                3, &e, &a, None, PayloadKind::Int8, FormatVersion::V2,
            )
            .unwrap(),
            encode_bundle_rff_at(
                3, &e, &a, &toy_rff(), None, FormatVersion::V2,
            )
            .unwrap(),
        ];
        for bytes in bundles {
            let hdr = peek_header(&bytes).unwrap();
            assert_eq!(hdr.version, FORMAT_V2);
            assert_eq!(hdr.format(), FormatVersion::V2);
            for f in record_frames(&bytes).unwrap() {
                assert_eq!(
                    f.payload_offset % PAYLOAD_ALIGN,
                    0,
                    "kind {} payload at {}",
                    f.kind,
                    f.payload_offset
                );
            }
        }
    }

    #[test]
    fn v2_decodes_to_the_same_models_and_reencodes_stably() {
        let e = toy_svm();
        let a = toy_approx();
        for kind in [PayloadKind::F32, PayloadKind::F16, PayloadKind::Int8] {
            let v1 =
                encode_bundle_quantized(5, &e, &a, None, kind).unwrap();
            let v2 = encode_bundle_quantized_at(
                5,
                &e,
                &a,
                None,
                kind,
                FormatVersion::V2,
            )
            .unwrap();
            let b1 = decode_bundle_full(&v1).unwrap();
            let b2 = decode_bundle_full(&v2).unwrap();
            assert_eq!(b1.format, FormatVersion::V1);
            assert_eq!(b2.format, FormatVersion::V2);
            // Same logical model through either container.
            assert_eq!(b1.exact_dequant().coef, b2.exact_dequant().coef);
            assert_eq!(
                b1.exact_dequant().sv.max_abs_diff(&b2.exact_dequant().sv),
                0.0
            );
            assert_eq!(b1.approx_dequant().v, b2.approx_dequant().v);
            assert_eq!(
                b1.approx_dequant().m.max_abs_diff(&b2.approx_dequant().m),
                0.0
            );
            // Byte-stability holds per format: encode(decode(x)) == x.
            let again = encode_bundle_native_at(
                5,
                &b2.models,
                b2.policy.as_ref(),
                FormatVersion::V2,
            )
            .unwrap();
            assert_eq!(again, v2, "{kind}: v2 native re-encode drifted");
        }
        // Rff bundles too.
        let rff = toy_rff();
        let v2 = encode_bundle_rff_at(
            9,
            &e,
            &a,
            &rff,
            None,
            FormatVersion::V2,
        )
        .unwrap();
        let b = decode_bundle_full(&v2).unwrap();
        assert_eq!(b.format, FormatVersion::V2);
        let TenantModels::Rff { rff: back, .. } = &b.models else {
            panic!("expected an rff bundle");
        };
        assert_eq!(back.w, rff.w);
        assert_eq!(
            encode_bundle_native_at(
                9,
                &b.models,
                b.policy.as_ref(),
                FormatVersion::V2
            )
            .unwrap(),
            v2
        );
        // The 28-byte prefix is format-independent, so the cheap peek
        // works unchanged on v2.
        let s = peek_rff_summary(&v2).unwrap().unwrap();
        assert_eq!(s.n_features, 64);
        assert_eq!(s.seed, rff.seed);
    }

    #[test]
    fn v2_mapped_decode_is_bit_identical_and_borrows() {
        let e = toy_svm();
        let a = toy_approx();
        for kind in [PayloadKind::F16, PayloadKind::Int8] {
            let bytes = encode_bundle_quantized_at(
                2,
                &e,
                &a,
                None,
                kind,
                FormatVersion::V2,
            )
            .unwrap();
            let map = Arc::new(MapFile::from_bytes(bytes.clone()));
            let mapped = decode_bundle_mapped(&map).unwrap();
            let heap = decode_bundle_full(&bytes).unwrap();
            // Bit-identical models whichever storage backs them.
            assert_eq!(
                mapped.exact_dequant().coef,
                heap.exact_dequant().coef
            );
            assert_eq!(
                mapped
                    .exact_dequant()
                    .sv
                    .max_abs_diff(&heap.exact_dequant().sv),
                0.0
            );
            assert_eq!(mapped.approx_dequant().v, heap.approx_dequant().v);
            assert_eq!(
                mapped
                    .approx_dequant()
                    .m
                    .max_abs_diff(&heap.approx_dequant().m),
                0.0
            );
            // The mapped decode actually borrows (on little-endian
            // hosts), the heap decode never does, and the two
            // accountings tile the same resident total.
            if cfg!(target_endian = "little") {
                assert!(mapped.models.mapped_bytes() > 0, "{kind}");
                assert!(
                    mapped.models.heap_bytes()
                        < heap.models.heap_bytes(),
                    "{kind}"
                );
            }
            assert_eq!(heap.models.mapped_bytes(), 0);
            assert_eq!(
                mapped.models.heap_bytes() + mapped.models.mapped_bytes(),
                mapped.models.resident_bytes()
            );
        }
        // Rff: the folded weights serve from the map; the regenerated
        // feature map gives bit-identical decisions.
        let bytes = encode_bundle_rff_at(
            2,
            &e,
            &a,
            &toy_rff(),
            None,
            FormatVersion::V2,
        )
        .unwrap();
        let map = Arc::new(MapFile::from_bytes(bytes.clone()));
        let mapped = decode_bundle_mapped(&map).unwrap();
        let heap = decode_bundle_full(&bytes).unwrap();
        let TenantModels::Rff { rff: rm, .. } = &mapped.models else {
            panic!("expected an rff bundle");
        };
        let TenantModels::Rff { rff: rh, .. } = &heap.models else {
            panic!("expected an rff bundle");
        };
        assert_eq!(rm.w, rh.w);
        let z = [0.4f32, -0.2, 1.0];
        assert_eq!(
            rm.decision_one(&z).0.to_bits(),
            rh.decision_one(&z).0.to_bits()
        );
        if cfg!(target_endian = "little") {
            assert!(rm.mapped_bytes() > 0);
        }
        // A v1 file through the mapped entry point heap-decodes.
        let v1 = encode_bundle_quantized(
            1,
            &e,
            &a,
            None,
            PayloadKind::Int8,
        )
        .unwrap();
        let map = Arc::new(MapFile::from_bytes(v1));
        let b = decode_bundle_mapped(&map).unwrap();
        assert_eq!(b.format, FormatVersion::V1);
        assert_eq!(b.models.mapped_bytes(), 0);
    }

    #[test]
    fn v2_pad_tampering_and_truncation_are_corrupt() {
        let bytes = encode_bundle_quantized_at(
            1,
            &toy_svm(),
            &toy_approx(),
            None,
            PayloadKind::Int8,
            FormatVersion::V2,
        )
        .unwrap();
        let frames = record_frames(&bytes).unwrap();
        let f = frames[0];
        assert!(f.pad > 0, "first record must need padding");
        // A corrupted pad count no longer places the payload on the
        // committed boundary.
        let mut bad = bytes.clone();
        let pad_off = f.payload_offset - f.pad as usize - 14;
        bad[pad_off] = bad[pad_off].wrapping_add(1);
        assert!(matches!(
            decode_bundle_full(&bad),
            Err(Error::Corrupt(m)) if m.contains("boundary")
        ));
        // Nonzero filler: the pad precedes the payload, so the CRC
        // does not cover it — the explicit zero check must refuse.
        let mut bad = bytes.clone();
        bad[f.payload_offset - 1] = 0xAA;
        assert!(matches!(
            decode_bundle_full(&bad),
            Err(Error::Corrupt(m)) if m.contains("padding")
        ));
        // Truncation inside the padding region stays typed.
        assert!(matches!(
            decode_bundle_full(&bytes[..f.payload_offset - 8]),
            Err(Error::Corrupt(_))
        ));
        // A flipped payload byte still fails the CRC on the aligned
        // layout, mapped or not.
        let mut bad = bytes.clone();
        bad[f.payload_offset] ^= 0x01;
        assert!(matches!(
            decode_bundle_full(&bad),
            Err(Error::Corrupt(m)) if m.contains("CRC-32")
        ));
        let map = Arc::new(MapFile::from_bytes(bad));
        assert!(matches!(
            decode_bundle_mapped(&map),
            Err(Error::Corrupt(m)) if m.contains("CRC-32")
        ));
        // Every prefix truncation of a v2 bundle is typed, never a
        // panic.
        for cut in 0..bytes.len() {
            assert!(matches!(
                decode_bundle_full(&bytes[..cut]),
                Err(Error::Corrupt(_))
            ));
        }
    }
}
