//! The engine-agnostic prediction surface.
//!
//! The paper's value proposition is a *drop-in replacement* for exact
//! RBF-SVM evaluation — so the crate exposes exactly one way to ask
//! "decision values for this batch, please": the [`Predictor`] trait.
//! The substrates implementing it:
//!
//! * [`crate::svm::ExactPredictor`] — the `O(n_SV·d)` exact evaluator
//!   (paper's Table 2 "exact" rows, Loops/Blocked math backends);
//! * [`ApproxPredictor`] — the `O(d²)` approximated model (Eq. 3.8),
//!   which also reports each instance's `‖z‖²` so the Eq. 3.11 validity
//!   check is free;
//! * `runtime::EngineApproxPredictor` / `runtime::EngineExactPredictor`
//!   (behind the `pjrt` feature) — the AOT-compiled XLA executables.
//!
//! * [`QuantApproxPredictor`] / [`QuantExactPredictor`] — the same two
//!   decision functions evaluated directly on **native quantized
//!   storage** (f16/int8 `.arbf` payloads, see
//!   [`crate::registry::quant`]): elements are dequantized on the fly,
//!   so a quantized tenant's resident footprint stays at the quantized
//!   size. The dequantization error is bounded and folded into the
//!   Eq. 3.11 routing budget by the serving executor.
//!
//! The serving layer ([`crate::coordinator`]) routes every batch through
//! this trait, so new backends (sharded, quantized, remote) slot in
//! behind a stable surface. Callers that want trait objects can: the
//! trait is object-safe (`&dyn Predictor` works).

use crate::linalg::Mat;
use crate::linalg::MathBackend;
use crate::approx::ApproxModel;
use crate::registry::quant::{
    PayloadKind, QuantApproxModel, QuantSvmModel,
};
use crate::{Error, Result};

/// Result of one batched evaluation.
#[derive(Clone, Debug)]
pub struct PredictOutput {
    /// Decision values f(z) (or f̂(z)), one per input row.
    pub decisions: Vec<f32>,
    /// `‖z‖²` per row when the substrate computes it as a by-product
    /// (the approx path always does — paper §3.1: the bound check is
    /// free there). `None` when the substrate does not surface norms.
    pub znorms_sq: Option<Vec<f32>>,
}

impl PredictOutput {
    /// Predicted ±1 labels (`sign(decision)`, with `0 → +1`).
    pub fn labels(&self) -> Vec<f32> {
        crate::svm::predict::labels_from_decisions(&self.decisions)
    }
}

/// One uniform evaluation interface over every backend.
///
/// Contract: `predict_batch` returns exactly `z.rows()` decisions (and,
/// when present, exactly `z.rows()` norms), or a typed error — it never
/// silently truncates. Inputs whose column count disagrees with
/// [`Predictor::dim`] must be rejected with [`Error::Shape`].
pub trait Predictor {
    /// Feature dimension this predictor evaluates.
    fn dim(&self) -> usize;

    /// Short substrate label for diagnostics/metrics (e.g.
    /// `"exact-native"`, `"approx-native"`, `"approx-xla"`).
    fn kind(&self) -> &'static str;

    /// Decision values for every row of `z`.
    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput>;

    /// Convenience: one instance. Default goes through
    /// [`Predictor::predict_batch`] with a 1-row matrix.
    fn predict_one(&self, z: &[f32]) -> Result<f32> {
        let m = Mat::from_rows(&[z])?;
        let out = self.predict_batch(&m)?;
        out.decisions.first().copied().ok_or_else(|| {
            Error::Other(format!(
                "{}: empty output for a 1-row batch",
                self.kind()
            ))
        })
    }
}

/// The approximated model bound to a math backend — the `O(d²)` fast
/// path as a [`Predictor`].
///
/// Borrows the model: the serving executor keeps models resident behind
/// `Arc`s and constructs this (cheap, two words) per batch.
pub struct ApproxPredictor<'m> {
    model: &'m ApproxModel,
    backend: MathBackend,
}

impl<'m> ApproxPredictor<'m> {
    /// `backend` must be a native backend; the XLA substrate lives in
    /// `runtime::EngineApproxPredictor`.
    pub fn new(
        model: &'m ApproxModel,
        backend: MathBackend,
    ) -> Result<ApproxPredictor<'m>> {
        if backend == MathBackend::Xla {
            return Err(Error::InvalidArg(
                "use runtime::EngineApproxPredictor for the XLA backend"
                    .into(),
            ));
        }
        Ok(ApproxPredictor { model, backend })
    }

    pub fn model(&self) -> &ApproxModel {
        self.model
    }
}

impl Predictor for ApproxPredictor<'_> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn kind(&self) -> &'static str {
        "approx-native"
    }

    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput> {
        let (decisions, norms) = self.model.decision_batch(z, self.backend)?;
        Ok(PredictOutput { decisions, znorms_sq: Some(norms) })
    }
}

/// The approximated model evaluated on **native quantized storage**
/// (f16/int8): `v` and the packed upper triangle of `M` are dequantized
/// element-wise inside the accumulation loops, so nothing f32-sized is
/// ever materialized. Row-independent scalar evaluation — decisions are
/// bit-stable across batch shapes and shard counts.
pub struct QuantApproxPredictor<'m> {
    model: &'m QuantApproxModel,
}

impl<'m> QuantApproxPredictor<'m> {
    pub fn new(model: &'m QuantApproxModel) -> QuantApproxPredictor<'m> {
        QuantApproxPredictor { model }
    }

    pub fn model(&self) -> &QuantApproxModel {
        self.model
    }
}

impl Predictor for QuantApproxPredictor<'_> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn kind(&self) -> &'static str {
        match self.model.payload() {
            PayloadKind::F16 => "approx-quant-f16",
            _ => "approx-quant-int8",
        }
    }

    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput> {
        if z.cols() != self.model.dim() {
            return Err(Error::Shape(format!(
                "batch dim {} vs model dim {}",
                z.cols(),
                self.model.dim()
            )));
        }
        let mut decisions = Vec::with_capacity(z.rows());
        let mut norms = Vec::with_capacity(z.rows());
        for r in 0..z.rows() {
            let (dec, zn) = self.model.decision_one(z.row(r));
            decisions.push(dec);
            norms.push(zn);
        }
        Ok(PredictOutput { decisions, znorms_sq: Some(norms) })
    }
}

/// The exact evaluator on **native quantized storage**: coefficients
/// and SV rows stay f16/int8 and are dequantized inside the per-SV
/// kernel loop (precomputed dequantized SV norms, like the f32 blocked
/// path). Row-independent evaluation, bit-stable across batch shapes.
pub struct QuantExactPredictor<'m> {
    model: &'m QuantSvmModel,
    sv_norms: Vec<f32>,
}

impl<'m> QuantExactPredictor<'m> {
    pub fn new(model: &'m QuantSvmModel) -> QuantExactPredictor<'m> {
        let sv_norms = model.sv_row_norms_sq();
        QuantExactPredictor { model, sv_norms }
    }

    /// Construct with precomputed (dequantized) SV norms — the serving
    /// executor caches them per model generation.
    pub fn with_norms(
        model: &'m QuantSvmModel,
        sv_norms: Vec<f32>,
    ) -> Result<QuantExactPredictor<'m>> {
        if sv_norms.len() != model.n_sv() {
            return Err(Error::Shape(format!(
                "{} SV norms vs {} SVs",
                sv_norms.len(),
                model.n_sv()
            )));
        }
        Ok(QuantExactPredictor { model, sv_norms })
    }
}

impl Predictor for QuantExactPredictor<'_> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn kind(&self) -> &'static str {
        match self.model.payload() {
            PayloadKind::F16 => "exact-quant-f16",
            _ => "exact-quant-int8",
        }
    }

    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput> {
        if z.cols() != self.model.dim() {
            return Err(Error::Shape(format!(
                "batch dim {} vs model dim {}",
                z.cols(),
                self.model.dim()
            )));
        }
        let m = self.model;
        let mut decisions = Vec::with_capacity(z.rows());
        for r in 0..z.rows() {
            let zr = z.row(r);
            let zn = crate::linalg::vecops::norm_sq(zr);
            let mut acc = m.b;
            for s in 0..m.n_sv() {
                let cross = m.sv.row_dot(s, zr);
                acc += m.coef.get(s)
                    * m.kernel.eval_precomp(self.sv_norms[s], zn, cross);
            }
            decisions.push(acc);
        }
        Ok(PredictOutput { decisions, znorms_sq: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::predict::ExactPredictor;
    use crate::svm::smo::{train_csvc, SmoParams};
    use crate::svm::Kernel;

    fn trained() -> (crate::svm::SvmModel, ApproxModel, crate::data::Dataset)
    {
        let ds = crate::data::synth::two_gaussians(3, 150, 5, 1.5);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let gamma = crate::approx::gamma_max_for_data(&scaled) * 0.8;
        let (m, _) =
            train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
                .unwrap();
        let am =
            crate::approx::build_approx_model(&m, MathBackend::Blocked)
                .unwrap();
        (m, am, scaled)
    }

    #[test]
    fn trait_objects_agree_with_direct_eval() {
        let (model, am, ds) = trained();
        let exact = ExactPredictor::new(&model, MathBackend::Blocked).unwrap();
        let approx = ApproxPredictor::new(&am, MathBackend::Blocked).unwrap();
        let predictors: Vec<&dyn Predictor> = vec![&exact, &approx];
        let z = ds.x.rows_slice(0, 20);
        for p in predictors {
            assert_eq!(p.dim(), ds.x.cols());
            let out = p.predict_batch(&z).unwrap();
            assert_eq!(out.decisions.len(), z.rows());
            for r in 0..z.rows() {
                let want = match p.kind() {
                    "exact-native" => model.decision_one(z.row(r)),
                    _ => am.decision_one(z.row(r)).0,
                };
                assert!(
                    (out.decisions[r] - want).abs() < 1e-3,
                    "{} row {r}: {} vs {want}",
                    p.kind(),
                    out.decisions[r]
                );
            }
        }
    }

    #[test]
    fn approx_predictor_reports_norms() {
        let (_, am, ds) = trained();
        let p = ApproxPredictor::new(&am, MathBackend::Loops).unwrap();
        let z = ds.x.rows_slice(0, 8);
        let out = p.predict_batch(&z).unwrap();
        let norms = out.znorms_sq.expect("approx path must report ‖z‖²");
        assert_eq!(norms.len(), 8);
        for (r, &n) in norms.iter().enumerate() {
            let want = crate::linalg::vecops::norm_sq(z.row(r));
            assert!((n - want).abs() < 1e-5);
        }
    }

    #[test]
    fn predict_one_default_matches_batch() {
        let (_, am, ds) = trained();
        let p = ApproxPredictor::new(&am, MathBackend::Blocked).unwrap();
        let z = ds.x.row(0);
        let one = p.predict_one(z).unwrap();
        let (want, _) = am.decision_one(z);
        assert!((one - want).abs() < 1e-4);
    }

    #[test]
    fn xla_backend_rejected() {
        let (_, am, _) = trained();
        assert!(ApproxPredictor::new(&am, MathBackend::Xla).is_err());
    }

    #[test]
    fn labels_sign_convention() {
        let out = PredictOutput {
            decisions: vec![0.25, -0.5, 0.0],
            znorms_sq: None,
        };
        assert_eq!(out.labels(), vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn quant_predictors_match_reference_within_reported_bounds() {
        let (model, am, ds) = trained();
        let z = ds.x.rows_slice(0, 24);
        for kind in [PayloadKind::F16, PayloadKind::Int8] {
            let qa = QuantApproxModel::quantize(&am, kind).unwrap();
            let qe = QuantSvmModel::quantize(&model, kind).unwrap();
            let ap = QuantApproxPredictor::new(&qa);
            let ep = QuantExactPredictor::new(&qe);
            assert_eq!(ap.dim(), am.dim());
            assert_eq!(ep.dim(), model.dim());
            assert!(ap.kind().starts_with("approx-quant"));
            assert!(ep.kind().starts_with("exact-quant"));
            let aout = ap.predict_batch(&z).unwrap();
            let eout = ep.predict_batch(&z).unwrap();
            assert_eq!(aout.decisions.len(), z.rows());
            assert_eq!(eout.decisions.len(), z.rows());
            let norms = aout.znorms_sq.expect("quant approx reports ‖z‖²");
            let a_err = qa.quant_err();
            let e_bound = qe.quant_err().decision_error();
            for r in 0..z.rows() {
                // Batch rows are bit-identical to per-row evaluation
                // (row-independent scalar path).
                let (one, zn) = qa.decision_one(z.row(r));
                assert_eq!(aout.decisions[r].to_bits(), one.to_bits());
                assert_eq!(norms[r].to_bits(), zn.to_bits());
                // And both stay within the advertised drift bounds of
                // their f32 twins.
                let (want_a, _) = am.decision_one(z.row(r));
                assert!(
                    (aout.decisions[r] - want_a).abs()
                        <= a_err.decision_error(zn),
                    "{kind} approx row {r}"
                );
                let want_e = model.decision_one(z.row(r));
                assert!(
                    (eout.decisions[r] - want_e).abs() <= e_bound,
                    "{kind} exact row {r}: |{} - {want_e}| > {e_bound}",
                    eout.decisions[r]
                );
            }
            // Trait objects work (object safety).
            let dyn_preds: Vec<&dyn Predictor> = vec![&ap, &ep];
            for p in dyn_preds {
                assert_eq!(p.predict_batch(&z).unwrap().decisions.len(), 24);
                let bad = Mat::zeros(2, am.dim() + 1);
                assert!(matches!(
                    p.predict_batch(&bad),
                    Err(Error::Shape(_))
                ));
            }
        }
    }
}
