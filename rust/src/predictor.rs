//! The engine-agnostic prediction surface.
//!
//! The paper's value proposition is a *drop-in replacement* for exact
//! RBF-SVM evaluation — so the crate exposes exactly one way to ask
//! "decision values for this batch, please": the [`Predictor`] trait.
//! The substrates implementing it:
//!
//! * [`crate::svm::ExactPredictor`] — the `O(n_SV·d)` exact evaluator
//!   (paper's Table 2 "exact" rows, Loops/Blocked math backends);
//! * [`ApproxPredictor`] — the `O(d²)` approximated model (Eq. 3.8),
//!   which also reports each instance's `‖z‖²` so the Eq. 3.11 validity
//!   check is free;
//! * `runtime::EngineApproxPredictor` / `runtime::EngineExactPredictor`
//!   (behind the `pjrt` feature) — the AOT-compiled XLA executables.
//!
//! * [`QuantApproxPredictor`] / [`QuantExactPredictor`] — the same two
//!   decision functions evaluated directly on **native quantized
//!   storage** (f16/int8 `.arbf` payloads, see
//!   [`crate::registry::quant`]) through the blocked/SIMD kernels in
//!   [`crate::linalg::quantblas`], so a quantized tenant's resident
//!   footprint stays at the quantized size without the scalar-loop
//!   throughput penalty. The kernel arm comes from the process-wide
//!   dispatch (`APPROXRBF_QUANT_KERNEL`) unless pinned via `with_arm`;
//!   int8 decisions are bit-identical across arms (exact integer
//!   accumulation). The dequantization error is bounded and folded
//!   into the Eq. 3.11 routing budget by the serving executor.
//!
//! * [`RffPredictor`] — the random-feature substrate
//!   ([`crate::approx::RffModel`], kind-6 `.arbf` bundles): `O(D·d)`
//!   fused cosine-feature evaluation through the
//!   [`crate::linalg::rffmap`] kernels, arm-dispatched via
//!   `APPROXRBF_RFF_KERNEL` unless pinned with `with_arm`; decisions
//!   are bit-identical across arms. Routing uses the model's stored
//!   Monte-Carlo error estimate instead of a ‖z‖² budget.
//!
//! The serving layer ([`crate::coordinator`]) routes every batch through
//! this trait, so new backends (sharded, quantized, remote) slot in
//! behind a stable surface. Callers that want trait objects can: the
//! trait is object-safe (`&dyn Predictor` works).

#![forbid(unsafe_code)]

use crate::linalg::quantblas;
use crate::linalg::rffmap;
use crate::linalg::KernelArm;
use crate::linalg::Mat;
use crate::linalg::MathBackend;
use crate::linalg::RffArm;
use crate::approx::{ApproxModel, RffModel};
use crate::registry::quant::{
    PayloadKind, QuantApproxModel, QuantSvmModel,
};
use crate::{Error, Result};

/// Result of one batched evaluation.
#[derive(Clone, Debug)]
pub struct PredictOutput {
    /// Decision values f(z) (or f̂(z)), one per input row.
    pub decisions: Vec<f32>,
    /// `‖z‖²` per row when the substrate computes it as a by-product
    /// (the approx path always does — paper §3.1: the bound check is
    /// free there). `None` when the substrate does not surface norms.
    pub znorms_sq: Option<Vec<f32>>,
}

impl PredictOutput {
    /// Predicted ±1 labels (`sign(decision)`, with `0 → +1`).
    pub fn labels(&self) -> Vec<f32> {
        crate::svm::predict::labels_from_decisions(&self.decisions)
    }
}

/// One uniform evaluation interface over every backend.
///
/// Contract: `predict_batch` returns exactly `z.rows()` decisions (and,
/// when present, exactly `z.rows()` norms), or a typed error — it never
/// silently truncates. Inputs whose column count disagrees with
/// [`Predictor::dim`] must be rejected with [`Error::Shape`].
pub trait Predictor {
    /// Feature dimension this predictor evaluates.
    fn dim(&self) -> usize;

    /// Short substrate label for diagnostics/metrics (e.g.
    /// `"exact-native"`, `"approx-native"`, `"approx-xla"`).
    fn kind(&self) -> &'static str;

    /// Decision values for every row of `z`.
    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput>;

    /// Convenience: one instance. Default goes through
    /// [`Predictor::predict_batch`] with a 1-row matrix.
    fn predict_one(&self, z: &[f32]) -> Result<f32> {
        let m = Mat::from_rows(&[z])?;
        let out = self.predict_batch(&m)?;
        out.decisions.first().copied().ok_or_else(|| {
            Error::Other(format!(
                "{}: empty output for a 1-row batch",
                self.kind()
            ))
        })
    }
}

/// The approximated model bound to a math backend — the `O(d²)` fast
/// path as a [`Predictor`].
///
/// Borrows the model: the serving executor keeps models resident behind
/// `Arc`s and constructs this (cheap, two words) per batch.
pub struct ApproxPredictor<'m> {
    model: &'m ApproxModel,
    backend: MathBackend,
}

impl<'m> ApproxPredictor<'m> {
    /// `backend` must be a native backend; the XLA substrate lives in
    /// `runtime::EngineApproxPredictor`.
    pub fn new(
        model: &'m ApproxModel,
        backend: MathBackend,
    ) -> Result<ApproxPredictor<'m>> {
        if backend == MathBackend::Xla {
            return Err(Error::InvalidArg(
                "use runtime::EngineApproxPredictor for the XLA backend"
                    .into(),
            ));
        }
        Ok(ApproxPredictor { model, backend })
    }

    pub fn model(&self) -> &ApproxModel {
        self.model
    }
}

impl Predictor for ApproxPredictor<'_> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn kind(&self) -> &'static str {
        "approx-native"
    }

    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput> {
        let (decisions, norms) = self.model.decision_batch(z, self.backend)?;
        Ok(PredictOutput { decisions, znorms_sq: Some(norms) })
    }
}

/// The approximated model evaluated on **native quantized storage**
/// (f16/int8) through the `linalg::quantblas` kernels: f16 rows
/// block-dequantize into FMA loops, int8 rows run exact-integer
/// i8×i16 kernels against a query quantized once per row, so nothing
/// f32-sized is ever materialized. Row-independent evaluation —
/// decisions are bit-stable across batch shapes and shard counts, and
/// (int8) across kernel arms.
pub struct QuantApproxPredictor<'m> {
    model: &'m QuantApproxModel,
    arm: KernelArm,
}

impl<'m> QuantApproxPredictor<'m> {
    /// Evaluate with the process-wide kernel arm
    /// (`APPROXRBF_QUANT_KERNEL`, else best available).
    pub fn new(model: &'m QuantApproxModel) -> QuantApproxPredictor<'m> {
        Self::with_arm(model, quantblas::active_arm())
    }

    /// Pin a specific kernel arm (A/B benches, dispatch-parity tests).
    pub fn with_arm(
        model: &'m QuantApproxModel,
        arm: KernelArm,
    ) -> QuantApproxPredictor<'m> {
        QuantApproxPredictor { model, arm }
    }

    pub fn model(&self) -> &QuantApproxModel {
        self.model
    }

    pub fn arm(&self) -> KernelArm {
        self.arm
    }
}

impl Predictor for QuantApproxPredictor<'_> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn kind(&self) -> &'static str {
        match self.model.payload() {
            PayloadKind::F16 => "approx-quant-f16",
            _ => "approx-quant-int8",
        }
    }

    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput> {
        if z.cols() != self.model.dim() {
            return Err(Error::Shape(format!(
                "batch dim {} vs model dim {}",
                z.cols(),
                self.model.dim()
            )));
        }
        let mut decisions = Vec::with_capacity(z.rows());
        let mut norms = Vec::with_capacity(z.rows());
        for r in 0..z.rows() {
            let (dec, zn) = self.model.decision_one_with(self.arm, z.row(r));
            decisions.push(dec);
            norms.push(zn);
        }
        Ok(PredictOutput { decisions, znorms_sq: Some(norms) })
    }
}

/// The random-feature substrate as a [`Predictor`]: the fused
/// `cos(Wx+b)`-feature decision kernel in [`crate::linalg::rffmap`],
/// `O(D·d)` per row with no `O(n_SV)` term anywhere. Row-independent
/// evaluation — decisions are bit-stable across batch shapes, shard
/// counts, and kernel arms (both arms accumulate in the same order).
pub struct RffPredictor<'m> {
    model: &'m RffModel,
    arm: RffArm,
}

impl<'m> RffPredictor<'m> {
    /// Evaluate with the process-wide kernel arm
    /// (`APPROXRBF_RFF_KERNEL`, else blocked).
    pub fn new(model: &'m RffModel) -> RffPredictor<'m> {
        Self::with_arm(model, rffmap::active_rff_arm())
    }

    /// Pin a specific kernel arm (A/B benches, dispatch-parity tests).
    pub fn with_arm(model: &'m RffModel, arm: RffArm) -> RffPredictor<'m> {
        RffPredictor { model, arm }
    }

    pub fn model(&self) -> &RffModel {
        self.model
    }

    pub fn arm(&self) -> RffArm {
        self.arm
    }
}

impl Predictor for RffPredictor<'_> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn kind(&self) -> &'static str {
        "approx-rff"
    }

    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput> {
        if z.cols() != self.model.dim() {
            return Err(Error::Shape(format!(
                "batch dim {} vs model dim {}",
                z.cols(),
                self.model.dim()
            )));
        }
        let mut decisions = Vec::with_capacity(z.rows());
        let mut norms = Vec::with_capacity(z.rows());
        for r in 0..z.rows() {
            let (dec, zn) = self.model.decision_one_with(self.arm, z.row(r));
            decisions.push(dec);
            norms.push(zn);
        }
        Ok(PredictOutput { decisions, znorms_sq: Some(norms) })
    }
}

/// The exact evaluator on **native quantized storage**: coefficients
/// and SV rows stay f16/int8 and stream through the
/// `linalg::quantblas` SV-matrix × z kernels (precomputed dequantized
/// SV norms, like the f32 blocked path; int8 queries quantize once
/// per row). Row-independent evaluation, bit-stable across batch
/// shapes and (int8) across kernel arms.
pub struct QuantExactPredictor<'m> {
    model: &'m QuantSvmModel,
    sv_norms: Vec<f32>,
    arm: KernelArm,
}

impl<'m> QuantExactPredictor<'m> {
    /// Evaluate with the process-wide kernel arm
    /// (`APPROXRBF_QUANT_KERNEL`, else best available).
    pub fn new(model: &'m QuantSvmModel) -> QuantExactPredictor<'m> {
        let sv_norms = model.sv_row_norms_sq();
        QuantExactPredictor {
            model,
            sv_norms,
            arm: quantblas::active_arm(),
        }
    }

    /// Pin a specific kernel arm (A/B benches, dispatch-parity tests).
    pub fn with_arm(
        model: &'m QuantSvmModel,
        arm: KernelArm,
    ) -> QuantExactPredictor<'m> {
        let sv_norms = model.sv_row_norms_sq();
        QuantExactPredictor { model, sv_norms, arm }
    }

    /// Construct with precomputed (dequantized) SV norms — the serving
    /// executor caches them per model generation.
    pub fn with_norms(
        model: &'m QuantSvmModel,
        sv_norms: Vec<f32>,
    ) -> Result<QuantExactPredictor<'m>> {
        if sv_norms.len() != model.n_sv() {
            return Err(Error::Shape(format!(
                "{} SV norms vs {} SVs",
                sv_norms.len(),
                model.n_sv()
            )));
        }
        Ok(QuantExactPredictor {
            model,
            sv_norms,
            arm: quantblas::active_arm(),
        })
    }

    pub fn arm(&self) -> KernelArm {
        self.arm
    }
}

impl Predictor for QuantExactPredictor<'_> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn kind(&self) -> &'static str {
        match self.model.payload() {
            PayloadKind::F16 => "exact-quant-f16",
            _ => "exact-quant-int8",
        }
    }

    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput> {
        if z.cols() != self.model.dim() {
            return Err(Error::Shape(format!(
                "batch dim {} vs model dim {}",
                z.cols(),
                self.model.dim()
            )));
        }
        let mut decisions = Vec::with_capacity(z.rows());
        for r in 0..z.rows() {
            decisions.push(self.model.decision_with_norms(
                self.arm,
                z.row(r),
                Some(&self.sv_norms),
            ));
        }
        Ok(PredictOutput { decisions, znorms_sq: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::predict::ExactPredictor;
    use crate::svm::smo::{train_csvc, SmoParams};
    use crate::svm::Kernel;

    fn trained() -> (crate::svm::SvmModel, ApproxModel, crate::data::Dataset)
    {
        let ds = crate::data::synth::two_gaussians(3, 150, 5, 1.5);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let gamma = crate::approx::gamma_max_for_data(&scaled) * 0.8;
        let (m, _) =
            train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
                .unwrap();
        let am =
            crate::approx::build_approx_model(&m, MathBackend::Blocked)
                .unwrap();
        (m, am, scaled)
    }

    #[test]
    fn trait_objects_agree_with_direct_eval() {
        let (model, am, ds) = trained();
        let exact = ExactPredictor::new(&model, MathBackend::Blocked).unwrap();
        let approx = ApproxPredictor::new(&am, MathBackend::Blocked).unwrap();
        let predictors: Vec<&dyn Predictor> = vec![&exact, &approx];
        let z = ds.x.rows_slice(0, 20);
        for p in predictors {
            assert_eq!(p.dim(), ds.x.cols());
            let out = p.predict_batch(&z).unwrap();
            assert_eq!(out.decisions.len(), z.rows());
            for r in 0..z.rows() {
                let want = match p.kind() {
                    "exact-native" => model.decision_one(z.row(r)),
                    _ => am.decision_one(z.row(r)).0,
                };
                assert!(
                    (out.decisions[r] - want).abs() < 1e-3,
                    "{} row {r}: {} vs {want}",
                    p.kind(),
                    out.decisions[r]
                );
            }
        }
    }

    #[test]
    fn approx_predictor_reports_norms() {
        let (_, am, ds) = trained();
        let p = ApproxPredictor::new(&am, MathBackend::Loops).unwrap();
        let z = ds.x.rows_slice(0, 8);
        let out = p.predict_batch(&z).unwrap();
        let norms = out.znorms_sq.expect("approx path must report ‖z‖²");
        assert_eq!(norms.len(), 8);
        for (r, &n) in norms.iter().enumerate() {
            let want = crate::linalg::vecops::norm_sq(z.row(r));
            assert!((n - want).abs() < 1e-5);
        }
    }

    #[test]
    fn predict_one_default_matches_batch() {
        let (_, am, ds) = trained();
        let p = ApproxPredictor::new(&am, MathBackend::Blocked).unwrap();
        let z = ds.x.row(0);
        let one = p.predict_one(z).unwrap();
        let (want, _) = am.decision_one(z);
        assert!((one - want).abs() < 1e-4);
    }

    #[test]
    fn xla_backend_rejected() {
        let (_, am, _) = trained();
        assert!(ApproxPredictor::new(&am, MathBackend::Xla).is_err());
    }

    #[test]
    fn labels_sign_convention() {
        let out = PredictOutput {
            decisions: vec![0.25, -0.5, 0.0],
            znorms_sq: None,
        };
        assert_eq!(out.labels(), vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn quant_predictors_match_reference_within_reported_bounds() {
        let (model, am, ds) = trained();
        let z = ds.x.rows_slice(0, 24);
        for kind in [PayloadKind::F16, PayloadKind::Int8] {
            let qa = QuantApproxModel::quantize(&am, kind).unwrap();
            let qe = QuantSvmModel::quantize(&model, kind).unwrap();
            let ap = QuantApproxPredictor::new(&qa);
            let ep = QuantExactPredictor::new(&qe);
            assert_eq!(ap.dim(), am.dim());
            assert_eq!(ep.dim(), model.dim());
            assert!(ap.kind().starts_with("approx-quant"));
            assert!(ep.kind().starts_with("exact-quant"));
            let aout = ap.predict_batch(&z).unwrap();
            let eout = ep.predict_batch(&z).unwrap();
            assert_eq!(aout.decisions.len(), z.rows());
            assert_eq!(eout.decisions.len(), z.rows());
            let norms = aout.znorms_sq.expect("quant approx reports ‖z‖²");
            let a_err = qa.quant_err();
            let e_err = qe.quant_err();
            for r in 0..z.rows() {
                // Batch rows are bit-identical to per-row evaluation
                // (row-independent kernel path).
                let (one, zn) = qa.decision_one(z.row(r));
                assert_eq!(aout.decisions[r].to_bits(), one.to_bits());
                assert_eq!(norms[r].to_bits(), zn.to_bits());
                // And both stay within the advertised drift bounds of
                // their f32 twins.
                let (want_a, _) = am.decision_one(z.row(r));
                assert!(
                    (aout.decisions[r] - want_a).abs()
                        <= a_err.decision_error(zn),
                    "{kind} approx row {r}"
                );
                let want_e = model.decision_one(z.row(r));
                let e_bound = e_err.decision_error_at(zn);
                assert!(
                    (eout.decisions[r] - want_e).abs() <= e_bound,
                    "{kind} exact row {r}: |{} - {want_e}| > {e_bound}",
                    eout.decisions[r]
                );
            }
            // Trait objects work (object safety).
            let dyn_preds: Vec<&dyn Predictor> = vec![&ap, &ep];
            for p in dyn_preds {
                assert_eq!(p.predict_batch(&z).unwrap().decisions.len(), 24);
                let bad = Mat::zeros(2, am.dim() + 1);
                assert!(matches!(
                    p.predict_batch(&bad),
                    Err(Error::Shape(_))
                ));
            }
        }
    }

    #[test]
    fn rff_predictor_matches_model_and_checks_shapes() {
        let (model, _, _) = trained();
        let rm = RffModel::fit(&model, Some(256), 7).unwrap();
        // SV rows sit inside the fit's probe set, so the stored
        // estimate provably covers them.
        let z = model.sv.rows_slice(0, model.n_sv().min(16));
        let p = RffPredictor::new(&rm);
        assert_eq!(p.dim(), model.dim());
        assert_eq!(p.kind(), "approx-rff");
        let out = p.predict_batch(&z).unwrap();
        assert_eq!(out.decisions.len(), z.rows());
        let norms = out.znorms_sq.expect("rff path must report ‖z‖²");
        for r in 0..z.rows() {
            // Batch rows are bit-identical to per-row evaluation and
            // across arms (row-independent, order-stable kernels).
            let (one, zn) = rm.decision_one(z.row(r));
            assert_eq!(out.decisions[r].to_bits(), one.to_bits());
            assert_eq!(norms[r].to_bits(), zn.to_bits());
            // On training-adjacent inputs the fitted map stays within
            // its stored estimate of the exact machine.
            let want = model.decision_one(z.row(r));
            assert!(
                (out.decisions[r] - want).abs() <= rm.err_est,
                "row {r}: |{} - {want}| > {}",
                out.decisions[r],
                rm.err_est
            );
        }
        for arm in rffmap::rff_available_arms() {
            let pinned = RffPredictor::with_arm(&rm, arm);
            assert_eq!(pinned.arm(), arm);
            let pout = pinned.predict_batch(&z).unwrap();
            for r in 0..z.rows() {
                assert_eq!(
                    pout.decisions[r].to_bits(),
                    out.decisions[r].to_bits(),
                    "{arm} row {r}"
                );
            }
        }
        // Shape contract + object safety.
        let dyn_p: &dyn Predictor = &p;
        let bad = Mat::zeros(2, model.dim() + 1);
        assert!(matches!(dyn_p.predict_batch(&bad), Err(Error::Shape(_))));
    }

    #[test]
    fn quant_predictor_arms_bit_identical_int8_bounded_f16() {
        let (model, am, ds) = trained();
        let z = ds.x.rows_slice(0, 16);
        // int8: every dispatch arm returns the scalar oracle's bits
        // (exact integer accumulation).
        let qa = QuantApproxModel::quantize(&am, PayloadKind::Int8).unwrap();
        let qe = QuantSvmModel::quantize(&model, PayloadKind::Int8).unwrap();
        let ref_a = QuantApproxPredictor::with_arm(&qa, KernelArm::Scalar)
            .predict_batch(&z)
            .unwrap();
        let ref_e = QuantExactPredictor::with_arm(&qe, KernelArm::Scalar)
            .predict_batch(&z)
            .unwrap();
        for arm in quantblas::available_arms() {
            let ap = QuantApproxPredictor::with_arm(&qa, arm);
            assert_eq!(ap.arm(), arm);
            let aout = ap.predict_batch(&z).unwrap();
            let eout = QuantExactPredictor::with_arm(&qe, arm)
                .predict_batch(&z)
                .unwrap();
            for r in 0..z.rows() {
                assert_eq!(
                    aout.decisions[r].to_bits(),
                    ref_a.decisions[r].to_bits(),
                    "{arm} approx row {r}"
                );
                assert_eq!(
                    eout.decisions[r].to_bits(),
                    ref_e.decisions[r].to_bits(),
                    "{arm} exact row {r}"
                );
            }
        }
        // f16: arms agree within the advertised bound of the f32 twin
        // (float reordering differs, so only bound-level agreement).
        let fa = QuantApproxModel::quantize(&am, PayloadKind::F16).unwrap();
        let f_err = fa.quant_err();
        for arm in quantblas::available_arms() {
            let out = QuantApproxPredictor::with_arm(&fa, arm)
                .predict_batch(&z)
                .unwrap();
            let norms = out.znorms_sq.expect("quant approx reports ‖z‖²");
            for r in 0..z.rows() {
                let (want, _) = am.decision_one(z.row(r));
                assert!(
                    (out.decisions[r] - want).abs()
                        <= f_err.decision_error(norms[r]),
                    "{arm} f16 row {r}"
                );
            }
        }
    }
}
