//! The engine-agnostic prediction surface.
//!
//! The paper's value proposition is a *drop-in replacement* for exact
//! RBF-SVM evaluation — so the crate exposes exactly one way to ask
//! "decision values for this batch, please": the [`Predictor`] trait.
//! Three substrates implement it:
//!
//! * [`crate::svm::ExactPredictor`] — the `O(n_SV·d)` exact evaluator
//!   (paper's Table 2 "exact" rows, Loops/Blocked math backends);
//! * [`ApproxPredictor`] — the `O(d²)` approximated model (Eq. 3.8),
//!   which also reports each instance's `‖z‖²` so the Eq. 3.11 validity
//!   check is free;
//! * `runtime::EngineApproxPredictor` / `runtime::EngineExactPredictor`
//!   (behind the `pjrt` feature) — the AOT-compiled XLA executables.
//!
//! The serving layer ([`crate::coordinator`]) routes every batch through
//! this trait, so new backends (sharded, quantized, remote) slot in
//! behind a stable surface. Callers that want trait objects can: the
//! trait is object-safe (`&dyn Predictor` works).

use crate::linalg::Mat;
use crate::linalg::MathBackend;
use crate::approx::ApproxModel;
use crate::{Error, Result};

/// Result of one batched evaluation.
#[derive(Clone, Debug)]
pub struct PredictOutput {
    /// Decision values f(z) (or f̂(z)), one per input row.
    pub decisions: Vec<f32>,
    /// `‖z‖²` per row when the substrate computes it as a by-product
    /// (the approx path always does — paper §3.1: the bound check is
    /// free there). `None` when the substrate does not surface norms.
    pub znorms_sq: Option<Vec<f32>>,
}

impl PredictOutput {
    /// Predicted ±1 labels (`sign(decision)`, with `0 → +1`).
    pub fn labels(&self) -> Vec<f32> {
        crate::svm::predict::labels_from_decisions(&self.decisions)
    }
}

/// One uniform evaluation interface over every backend.
///
/// Contract: `predict_batch` returns exactly `z.rows()` decisions (and,
/// when present, exactly `z.rows()` norms), or a typed error — it never
/// silently truncates. Inputs whose column count disagrees with
/// [`Predictor::dim`] must be rejected with [`Error::Shape`].
pub trait Predictor {
    /// Feature dimension this predictor evaluates.
    fn dim(&self) -> usize;

    /// Short substrate label for diagnostics/metrics (e.g.
    /// `"exact-native"`, `"approx-native"`, `"approx-xla"`).
    fn kind(&self) -> &'static str;

    /// Decision values for every row of `z`.
    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput>;

    /// Convenience: one instance. Default goes through
    /// [`Predictor::predict_batch`] with a 1-row matrix.
    fn predict_one(&self, z: &[f32]) -> Result<f32> {
        let m = Mat::from_rows(&[z])?;
        let out = self.predict_batch(&m)?;
        out.decisions.first().copied().ok_or_else(|| {
            Error::Other(format!(
                "{}: empty output for a 1-row batch",
                self.kind()
            ))
        })
    }
}

/// The approximated model bound to a math backend — the `O(d²)` fast
/// path as a [`Predictor`].
///
/// Borrows the model: the serving executor keeps models resident behind
/// `Arc`s and constructs this (cheap, two words) per batch.
pub struct ApproxPredictor<'m> {
    model: &'m ApproxModel,
    backend: MathBackend,
}

impl<'m> ApproxPredictor<'m> {
    /// `backend` must be a native backend; the XLA substrate lives in
    /// `runtime::EngineApproxPredictor`.
    pub fn new(
        model: &'m ApproxModel,
        backend: MathBackend,
    ) -> Result<ApproxPredictor<'m>> {
        if backend == MathBackend::Xla {
            return Err(Error::InvalidArg(
                "use runtime::EngineApproxPredictor for the XLA backend"
                    .into(),
            ));
        }
        Ok(ApproxPredictor { model, backend })
    }

    pub fn model(&self) -> &ApproxModel {
        self.model
    }
}

impl Predictor for ApproxPredictor<'_> {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn kind(&self) -> &'static str {
        "approx-native"
    }

    fn predict_batch(&self, z: &Mat) -> Result<PredictOutput> {
        let (decisions, norms) = self.model.decision_batch(z, self.backend)?;
        Ok(PredictOutput { decisions, znorms_sq: Some(norms) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svm::predict::ExactPredictor;
    use crate::svm::smo::{train_csvc, SmoParams};
    use crate::svm::Kernel;

    fn trained() -> (crate::svm::SvmModel, ApproxModel, crate::data::Dataset)
    {
        let ds = crate::data::synth::two_gaussians(3, 150, 5, 1.5);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let gamma = crate::approx::gamma_max_for_data(&scaled) * 0.8;
        let (m, _) =
            train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
                .unwrap();
        let am =
            crate::approx::build_approx_model(&m, MathBackend::Blocked)
                .unwrap();
        (m, am, scaled)
    }

    #[test]
    fn trait_objects_agree_with_direct_eval() {
        let (model, am, ds) = trained();
        let exact = ExactPredictor::new(&model, MathBackend::Blocked).unwrap();
        let approx = ApproxPredictor::new(&am, MathBackend::Blocked).unwrap();
        let predictors: Vec<&dyn Predictor> = vec![&exact, &approx];
        let z = ds.x.rows_slice(0, 20);
        for p in predictors {
            assert_eq!(p.dim(), ds.x.cols());
            let out = p.predict_batch(&z).unwrap();
            assert_eq!(out.decisions.len(), z.rows());
            for r in 0..z.rows() {
                let want = match p.kind() {
                    "exact-native" => model.decision_one(z.row(r)),
                    _ => am.decision_one(z.row(r)).0,
                };
                assert!(
                    (out.decisions[r] - want).abs() < 1e-3,
                    "{} row {r}: {} vs {want}",
                    p.kind(),
                    out.decisions[r]
                );
            }
        }
    }

    #[test]
    fn approx_predictor_reports_norms() {
        let (_, am, ds) = trained();
        let p = ApproxPredictor::new(&am, MathBackend::Loops).unwrap();
        let z = ds.x.rows_slice(0, 8);
        let out = p.predict_batch(&z).unwrap();
        let norms = out.znorms_sq.expect("approx path must report ‖z‖²");
        assert_eq!(norms.len(), 8);
        for (r, &n) in norms.iter().enumerate() {
            let want = crate::linalg::vecops::norm_sq(z.row(r));
            assert!((n - want).abs() < 1e-5);
        }
    }

    #[test]
    fn predict_one_default_matches_batch() {
        let (_, am, ds) = trained();
        let p = ApproxPredictor::new(&am, MathBackend::Blocked).unwrap();
        let z = ds.x.row(0);
        let one = p.predict_one(z).unwrap();
        let (want, _) = am.decision_one(z);
        assert!((one - want).abs() < 1e-4);
    }

    #[test]
    fn xla_backend_rejected() {
        let (_, am, _) = trained();
        assert!(ApproxPredictor::new(&am, MathBackend::Xla).is_err());
    }

    #[test]
    fn labels_sign_convention() {
        let out = PredictOutput {
            decisions: vec![0.25, -0.5, 0.0],
            znorms_sq: None,
        };
        assert_eq!(out.labels(), vec![1.0, -1.0, 1.0]);
    }
}
