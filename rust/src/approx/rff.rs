//! Random-Fourier-feature substrate (Rahimi & Recht; the explicit-map
//! regime of Fastfood / Cotter et al. named in PAPERS.md).
//!
//! For the RBF kernel `K(x,y) = e^{−γ‖x−y‖²}`, Bochner's theorem gives
//! `K(x,y) ≈ z(x)·z(y)` with `z(x) = √(2/D)·cos(Wx + φ)`, `W ∈ ℝ^{D×d}`
//! with rows drawn from `N(0, 2γI)` and `φ ~ U[0, 2π)`. Folding the
//! dual weights at publish time,
//!
//! `f̂(z) = b + Σ_j w_j·cos(W_j·z + φ_j)`,
//! `w_j = (2/D)·Σ_i coef_i·cos(W_j·x_i + φ_j)`,
//!
//! an `O(D·d)` evaluation independent of `n_SV` — the regime the
//! Maclaurin approximation (quadratic in `d`, bound collapsing at large
//! γ) cannot serve fast.
//!
//! **The feature map is never stored.** `W` and `φ` regenerate from a
//! 64-bit seed through the deterministic [`crate::util::Rng`]
//! (xoshiro256++/SplitMix64) in one canonical draw order, so the
//! kind-6 `.arbf` record carries only *(seed, D, γ, b, error estimate,
//! w)* — `O(D)` bytes — and every shard/process that decodes it
//! reconstructs bit-identical `W`, `φ` and therefore bit-identical
//! decisions.
//!
//! The **empirical error estimate** is a Monte-Carlo bound computed at
//! publish over a deterministic probe set (the SVs, jittered SVs,
//! SV midpoints and rescaled SVs — the regions the model actually
//! discriminates in): `err_est = 3·max_probe|f̂ − f| + 1e-3`. It is
//! stored in the record and drives per-tenant substrate routing: a
//! tenant whose estimate exceeds the effective `quant_drift_tol`
//! escorts everything to the exact path (see
//! [`crate::registry::ModelEntry::znorm_sq_budget_with`]).

use crate::linalg::rffmap::{self, RffArm};
use crate::linalg::vecops;
use crate::registry::mapfile::TensorData;
use crate::svm::{Kernel, SvmModel};
use crate::util::Rng;
use crate::{Error, Result};

/// Default feature count `D` for publishes that don't pin one (the
/// adaptive fit doubles from here while the error estimate stays above
/// [`ADAPT_TARGET_ERR`]).
pub const DEFAULT_RFF_FEATURES: usize = 512;

/// Ceiling of the adaptive doubling ladder.
pub const ADAPT_MAX_RFF_FEATURES: usize = 4096;

/// Adaptive fit target: half the default routing tolerance, so an
/// unpinned RFF publish normally lands with headroom under
/// [`crate::approx::bounds::DEFAULT_QUANT_DRIFT_TOL`].
pub const ADAPT_TARGET_ERR: f32 =
    crate::approx::bounds::DEFAULT_QUANT_DRIFT_TOL * 0.5;

/// Probe-jitter scale of the error-estimate set (fraction of each SV
/// coordinate's unit, additive Gaussian).
const PROBE_JITTER: f64 = 0.05;

/// Safety factor and floor of the stored estimate:
/// `err_est = 3·worst_probe + 1e-3`.
const ERR_SAFETY: f32 = 3.0;
const ERR_FLOOR: f32 = 1e-3;

/// A fitted random-feature model: the stored record fields plus the
/// regenerated feature map.
#[derive(Clone, Debug)]
pub struct RffModel {
    /// PRNG seed the feature map regenerates from.
    pub seed: u64,
    /// RBF kernel width of the source model.
    pub gamma: f32,
    /// Bias term (the exact model's `b`).
    pub bias: f32,
    /// Stored Monte-Carlo decision-error estimate vs the exact model.
    pub err_est: f32,
    /// Folded output weights, length `D` (the `√(2/D)` feature scale
    /// and the `2/D` kernel-estimator scale are baked in). Owned for
    /// v1 decodes and fits; a borrowed view over the bundle file when
    /// decoded from a mapped format-v2 record.
    pub w: TensorData<f32>,
    /// Feature dimension `d`.
    dim: usize,
    /// Regenerated `D×d` row-major frequency matrix (not stored).
    wmat: Vec<f32>,
    /// Regenerated phases, length `D` (not stored).
    phase: Vec<f32>,
}

impl RffModel {
    /// Number of random features `D`.
    pub fn n_features(&self) -> usize {
        self.w.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The canonical feature-map draw order — **load-bearing for
    /// bit-identity** (the Box–Muller spare-deviate cache makes any
    /// reorder observable): all `D·d` frequencies row-major first,
    /// then all `D` phases.
    fn regenerate(
        seed: u64,
        n_features: usize,
        dim: usize,
        gamma: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let sigma = (2.0 * gamma as f64).sqrt();
        let wmat: Vec<f32> = (0..n_features * dim)
            .map(|_| (rng.normal() * sigma) as f32)
            .collect();
        let phase: Vec<f32> = (0..n_features)
            .map(|_| (rng.uniform() * std::f64::consts::TAU) as f32)
            .collect();
        (wmat, phase)
    }

    /// Reconstruct a model from its stored record fields, regenerating
    /// the feature map from the seed. This is the `.arbf` decode path;
    /// validation mirrors the other models' `check_finite` contracts.
    pub fn from_parts(
        dim: usize,
        seed: u64,
        gamma: f32,
        bias: f32,
        err_est: f32,
        w: impl Into<TensorData<f32>>,
    ) -> Result<RffModel> {
        let w = w.into();
        if dim == 0 || w.is_empty() {
            return Err(Error::InvalidArg(format!(
                "rff model needs dim ≥ 1 and D ≥ 1 (got d={dim}, D={})",
                w.len()
            )));
        }
        if !(gamma.is_finite() && gamma > 0.0) {
            return Err(Error::InvalidArg(format!(
                "rff model needs a finite positive gamma (got {gamma})"
            )));
        }
        if !bias.is_finite() {
            return Err(Error::InvalidArg(format!(
                "non-finite rff bias: {bias}"
            )));
        }
        if !(err_est.is_finite() && err_est >= 0.0) {
            return Err(Error::InvalidArg(format!(
                "rff err_est must be finite and ≥ 0 (got {err_est})"
            )));
        }
        if let Some(i) = w.iter().position(|x| !x.is_finite()) {
            return Err(Error::InvalidArg(format!("non-finite rff w[{i}]")));
        }
        let (wmat, phase) =
            RffModel::regenerate(seed, w.len(), dim, gamma);
        Ok(RffModel { seed, gamma, bias, err_est, w, dim, wmat, phase })
    }

    /// Fit a random-feature model to an exact RBF SVM: regenerate the
    /// map from `seed`, fold the dual weights, and compute the stored
    /// error estimate over the deterministic probe set. `n_features`
    /// pins `D`; `None` runs the adaptive ladder (double from
    /// [`DEFAULT_RFF_FEATURES`] until the estimate reaches
    /// [`ADAPT_TARGET_ERR`] or [`ADAPT_MAX_RFF_FEATURES`]).
    pub fn fit(
        exact: &SvmModel,
        n_features: Option<usize>,
        seed: u64,
    ) -> Result<RffModel> {
        let Kernel::Rbf { gamma } = exact.kernel else {
            return Err(Error::InvalidArg(format!(
                "the rff substrate requires an RBF kernel (got {:?})",
                exact.kernel
            )));
        };
        if !(gamma.is_finite() && gamma > 0.0) {
            return Err(Error::InvalidArg(format!(
                "rff fit needs a finite positive gamma (got {gamma})"
            )));
        }
        exact.check_finite().map_err(Error::InvalidArg)?;
        match n_features {
            Some(d_feat) => RffModel::fit_at(exact, gamma, d_feat, seed),
            None => {
                let mut d_feat = DEFAULT_RFF_FEATURES;
                loop {
                    let model =
                        RffModel::fit_at(exact, gamma, d_feat, seed)?;
                    if model.err_est <= ADAPT_TARGET_ERR
                        || d_feat >= ADAPT_MAX_RFF_FEATURES
                    {
                        return Ok(model);
                    }
                    d_feat *= 2;
                }
            }
        }
    }

    fn fit_at(
        exact: &SvmModel,
        gamma: f32,
        n_features: usize,
        seed: u64,
    ) -> Result<RffModel> {
        if n_features == 0 {
            return Err(Error::InvalidArg(
                "rff feature count D must be ≥ 1".into(),
            ));
        }
        let dim = exact.dim();
        if dim == 0 {
            return Err(Error::InvalidArg(
                "rff fit needs dim ≥ 1".into(),
            ));
        }
        let (wmat, phase) =
            RffModel::regenerate(seed, n_features, dim, gamma);
        // Fold the dual weights: w_j = (2/D)·Σ_i coef_i·cos(W_j·x_i + φ_j).
        let scale = 2.0 / n_features as f32;
        let mut w = vec![0f32; n_features];
        for j in 0..n_features {
            let row = &wmat[j * dim..(j + 1) * dim];
            let mut acc = 0f32;
            for i in 0..exact.n_sv() {
                let dot = vecops::dot(row, exact.sv.row(i));
                acc += exact.coef[i] * (dot + phase[j]).cos();
            }
            w[j] = scale * acc;
        }
        let mut model = RffModel {
            seed,
            gamma,
            bias: exact.b,
            err_est: 0.0,
            w: w.into(),
            dim,
            wmat,
            phase,
        };
        model.err_est = model.estimate_err(exact);
        Ok(model)
    }

    /// Monte-Carlo error estimate vs the exact model over a
    /// deterministic probe set anchored at the SVs: the SVs themselves,
    /// Gaussian-jittered copies, consecutive-pair midpoints, and
    /// rescaled copies (norm regimes above/below the data shell).
    fn estimate_err(&self, exact: &SvmModel) -> f32 {
        let mut rng = Rng::new(self.seed ^ 0x5052_4F42_4553_4554); // probe stream
        let n_sv = exact.n_sv();
        let d = self.dim;
        let mut worst = 0f32;
        let mut buf = vec![0f32; d];
        let mut check = |probe: &[f32], worst: &mut f32| {
            let diff =
                (self.decision_one(probe).0 - exact.decision_one(probe))
                    .abs();
            if diff > *worst {
                *worst = diff;
            }
        };
        for i in 0..n_sv {
            let sv = exact.sv.row(i);
            check(sv, &mut worst);
            for (k, &x) in sv.iter().enumerate() {
                buf[k] = x + (rng.normal() * PROBE_JITTER) as f32;
            }
            check(&buf, &mut worst);
            let next = exact.sv.row((i + 1) % n_sv);
            for k in 0..d {
                buf[k] = 0.5 * (sv[k] + next[k]);
            }
            check(&buf, &mut worst);
            let s = rng.range(0.5, 1.5) as f32;
            for k in 0..d {
                buf[k] = s * sv[k];
            }
            check(&buf, &mut worst);
        }
        ERR_SAFETY * worst + ERR_FLOOR
    }

    /// Decision value + `‖z‖²` for one instance through the
    /// process-wide kernel arm.
    pub fn decision_one(&self, z: &[f32]) -> (f32, f32) {
        self.decision_one_with(rffmap::active_rff_arm(), z)
    }

    /// Decision value + `‖z‖²` through an explicit kernel arm (A/B
    /// benches, dispatch-parity tests). Arms are bit-identical.
    pub fn decision_one_with(&self, arm: RffArm, z: &[f32]) -> (f32, f32) {
        debug_assert_eq!(z.len(), self.dim);
        let zn = vecops::norm_sq(z);
        let dec = rffmap::rff_decision(
            arm,
            &self.wmat,
            &self.phase,
            &self.w,
            self.dim,
            self.bias,
            z,
        );
        (dec, zn)
    }

    /// Resident footprint in bytes: the stored `w` plus the regenerated
    /// `W` and `φ` (the map is `O(D·d)` resident but `O(D)` on disk).
    pub fn resident_bytes(&self) -> usize {
        4 * (self.w.len() + self.wmat.len() + self.phase.len()) + 28
    }

    /// Heap share of [`RffModel::resident_bytes`]: the regenerated map
    /// always lives on the heap; `w` does only when owned (v1 decode
    /// or a fresh fit).
    pub fn heap_bytes(&self) -> usize {
        self.w.heap_bytes()
            + 4 * (self.wmat.len() + self.phase.len())
            + 28
    }

    /// Mapped-file share of [`RffModel::resident_bytes`] (`w` when
    /// decoded from a mapped format-v2 record).
    pub fn mapped_bytes(&self) -> usize {
        self.w.mapped_bytes()
    }
}

/// Deterministic per-tenant seed (FNV-1a over the model id): the same
/// id republished on any node folds the same feature map, and the seed
/// still travels in the record so decode never depends on this.
pub fn seed_for_id(id: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in id.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rffmap::rff_available_arms;
    use crate::linalg::Mat;

    fn toy_exact() -> SvmModel {
        SvmModel::new(
            Kernel::Rbf { gamma: 0.25 },
            Mat::from_vec(3, 3, vec![1., 0., 2., 0., 2., 0., -1., 1., 0.5])
                .unwrap(),
            vec![0.5, -1.0, 0.75],
            0.125,
        )
        .unwrap()
    }

    #[test]
    fn regeneration_is_bit_deterministic() {
        let exact = toy_exact();
        let a = RffModel::fit(&exact, Some(64), 42).unwrap();
        let b = RffModel::from_parts(
            a.dim(),
            a.seed,
            a.gamma,
            a.bias,
            a.err_est,
            a.w.clone(),
        )
        .unwrap();
        assert_eq!(a.wmat.len(), b.wmat.len());
        for (x, y) in a.wmat.iter().zip(&b.wmat) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.phase.iter().zip(&b.phase) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let z = [0.3f32, -0.7, 1.1];
        assert_eq!(
            a.decision_one(&z).0.to_bits(),
            b.decision_one(&z).0.to_bits()
        );
    }

    #[test]
    fn different_seeds_give_different_maps() {
        let exact = toy_exact();
        let a = RffModel::fit(&exact, Some(32), 1).unwrap();
        let b = RffModel::fit(&exact, Some(32), 2).unwrap();
        assert_ne!(a.wmat, b.wmat);
    }

    #[test]
    fn fit_approximates_exact_within_stored_estimate() {
        let exact = toy_exact();
        let m = RffModel::fit(&exact, Some(2048), 7).unwrap();
        assert_eq!(m.n_features(), 2048);
        assert_eq!(m.dim(), 3);
        assert!(m.err_est.is_finite() && m.err_est > 0.0);
        // Probe-adjacent points (tighter jitter than the estimate's own
        // probe set) must stay within the stored estimate.
        let mut rng = Rng::new(0xD00D);
        for i in 0..exact.n_sv() {
            let sv = exact.sv.row(i);
            let z: Vec<f32> = sv
                .iter()
                .map(|&x| x + (rng.normal() * 0.02) as f32)
                .collect();
            let got = m.decision_one(&z).0;
            let want = exact.decision_one(&z);
            assert!(
                (got - want).abs() <= m.err_est,
                "sv {i}: |{got} - {want}| > {}",
                m.err_est
            );
        }
    }

    #[test]
    fn adaptive_fit_tightens_until_target_or_cap() {
        let exact = toy_exact();
        let m = RffModel::fit(&exact, None, 11).unwrap();
        assert!(m.n_features() >= DEFAULT_RFF_FEATURES);
        assert!(m.n_features() <= ADAPT_MAX_RFF_FEATURES);
        assert!(
            m.err_est <= ADAPT_TARGET_ERR
                || m.n_features() == ADAPT_MAX_RFF_FEATURES
        );
    }

    #[test]
    fn arms_bit_identical_on_fitted_model() {
        let exact = toy_exact();
        let m = RffModel::fit(&exact, Some(129), 3).unwrap(); // odd D: tail path
        let z = [0.5f32, 0.25, -1.0];
        let (reference, zn) = m.decision_one_with(RffArm::Scalar, &z);
        assert!((zn - vecops::norm_sq(&z)).abs() < 1e-6);
        for arm in rff_available_arms() {
            let (got, _) = m.decision_one_with(arm, &z);
            assert_eq!(got.to_bits(), reference.to_bits(), "{arm}");
        }
    }

    #[test]
    fn non_rbf_kernels_rejected() {
        let linear = SvmModel::new(
            Kernel::Linear,
            Mat::from_vec(1, 2, vec![1., 2.]).unwrap(),
            vec![1.0],
            0.0,
        )
        .unwrap();
        assert!(matches!(
            RffModel::fit(&linear, Some(16), 1),
            Err(Error::InvalidArg(_))
        ));
    }

    #[test]
    fn from_parts_rejects_defects() {
        assert!(RffModel::from_parts(0, 1, 0.5, 0.0, 0.0, vec![1.0]).is_err());
        assert!(RffModel::from_parts(2, 1, 0.5, 0.0, 0.0, vec![]).is_err());
        assert!(
            RffModel::from_parts(2, 1, f32::NAN, 0.0, 0.0, vec![1.0])
                .is_err()
        );
        assert!(
            RffModel::from_parts(2, 1, -0.5, 0.0, 0.0, vec![1.0]).is_err()
        );
        assert!(
            RffModel::from_parts(2, 1, 0.5, f32::INFINITY, 0.0, vec![1.0])
                .is_err()
        );
        assert!(
            RffModel::from_parts(2, 1, 0.5, 0.0, -1.0, vec![1.0]).is_err()
        );
        assert!(
            RffModel::from_parts(2, 1, 0.5, 0.0, 0.0, vec![f32::NAN])
                .is_err()
        );
        assert!(RffModel::from_parts(2, 1, 0.5, 0.0, 0.0, vec![1.0]).is_ok());
    }

    #[test]
    fn seed_for_id_is_stable_and_spreads() {
        assert_eq!(seed_for_id("tenant"), seed_for_id("tenant"));
        assert_ne!(seed_for_id("tenant-a"), seed_for_id("tenant-b"));
    }

    #[test]
    fn resident_bytes_track_shapes() {
        let exact = toy_exact();
        let m = RffModel::fit(&exact, Some(64), 5).unwrap();
        // w: 64, wmat: 64·3, phase: 64 → 4·320 + 28.
        assert_eq!(m.resident_bytes(), 4 * (64 + 64 * 3 + 64) + 28);
    }
}
