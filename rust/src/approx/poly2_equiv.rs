//! Relation to the degree-2 polynomial kernel (paper §3.2,
//! Eqs. 3.12–3.16).
//!
//! A degree-2 polynomial kernel `κ(x,y) = (γ xᵀy + β)²` expands
//! *exactly* (not approximately) into the same quadratic form as the
//! RBF approximation:
//!
//! ```text
//! f(z) = c + wᵀXz + zᵀ X D Xᵀ z + b
//! c    = β² Σ αᵢyᵢ            (Eq. 3.14 right)
//! wᵢ   = 2βγ αᵢyᵢ             (Eq. 3.15 right)
//! Dᵢᵢ  = γ²  αᵢyᵢ             (Eq. 3.16 right)
//! ```
//!
//! The two differences the paper highlights (Eq. 3.13): the
//! approximated RBF model carries (i) the per-instance scaling
//! `exp(−γ‖z‖²)` and (ii) a 2× relative weight on second-order terms.
//! Within the validity bound the scaling factor is confined to
//! `(e^{−1/4}, 1]` (§3.2 last paragraph), which this module also
//! exposes and tests.

use crate::approx::ApproxModel;
use crate::linalg::syrk;
use crate::svm::{Kernel, SvmModel};
use crate::{Error, Result};

/// Lower bound of the extra RBF scaling factor `exp(−γ‖z‖²)` when the
/// validity bound holds and `‖x_M‖ ≥ ‖z‖`: `e^{−1/4}` (paper §3.2).
pub const MIN_SCALING_IN_BOUND: f64 = 0.778_800_783_071_404_9; // e^-0.25

/// Exact quadratic-form expansion of a degree-2 polynomial model.
///
/// Returns an [`ApproxModel`]-shaped object whose decision function —
/// *without* the `exp(−γ‖z‖²)` factor — reproduces the polynomial
/// model exactly. The `gamma` field is set to 0 so `decision_one`
/// (which multiplies by `exp(−0·‖z‖²) = 1`) is the exact polynomial
/// decision.
pub fn expand_poly2(model: &SvmModel) -> Result<ApproxModel> {
    let (gamma, beta) = match model.kernel {
        Kernel::Poly2 { gamma, beta } => (gamma, beta),
        ref k => {
            return Err(Error::InvalidArg(format!(
                "expected a degree-2 polynomial kernel, got {}",
                k.name()
            )))
        }
    };
    let n = model.n_sv();
    // Eq. 3.14–3.16, right-hand column.
    let mut c = 0.0f64;
    let mut w = Vec::with_capacity(n);
    let mut dd = Vec::with_capacity(n);
    for i in 0..n {
        let ay = f64::from(model.coef[i]);
        c += f64::from(beta) * f64::from(beta) * ay;
        w.push(2.0 * beta * gamma * model.coef[i]);
        dd.push(gamma * gamma * model.coef[i]);
    }
    Ok(ApproxModel {
        gamma: 0.0, // exp(−0·‖z‖²) = 1: expansion is exact
        b: model.b,
        c: c as f32,
        v: syrk::xt_w(&model.sv, &w),
        m: syrk::syrk_weighted_blocked(&model.sv, &dd),
        max_sv_norm_sq: model.max_sv_norm_sq(),
    })
}

/// The per-instance scaling factor `exp(−γ‖z‖²)` that distinguishes an
/// approximated RBF model from an exact polynomial model (Eq. 3.13).
pub fn rbf_extra_scaling(gamma: f32, znorm_sq: f32) -> f64 {
    f64::from(-gamma * znorm_sq).exp()
}

/// Convert an RBF approximation into the "equivalent-effect" degree-2
/// polynomial coefficients of §3.2: α⁽²ᴰ⁾ᵢ = α⁽ᴿᴮᶠ⁾ᵢ·e^{−γ‖xᵢ‖²}
/// (the SV-side exponentials folded into the coefficients, β = 1).
pub fn equivalent_poly2_coefficients(model: &SvmModel) -> Result<Vec<f32>> {
    let gamma = match model.kernel {
        Kernel::Rbf { gamma } => gamma,
        ref k => {
            return Err(Error::InvalidArg(format!(
                "expected an RBF kernel, got {}",
                k.name()
            )))
        }
    };
    Ok((0..model.n_sv())
        .map(|i| {
            let nsq = crate::linalg::vecops::norm_sq(model.sv.row(i));
            model.coef[i] * (-gamma * nsq).exp()
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::linalg::{Mat, MathBackend};
    use crate::svm::smo::{train_csvc, SmoParams};

    fn poly_model() -> (SvmModel, crate::data::Dataset) {
        let ds = synth::two_gaussians(81, 200, 6, 2.0);
        let (m, _) = train_csvc(
            &ds,
            Kernel::Poly2 { gamma: 0.5, beta: 1.0 },
            SmoParams::default(),
        )
        .unwrap();
        (m, ds)
    }

    #[test]
    fn expansion_is_exact_not_approximate() {
        // The paper's key contrast (§3.2): for poly2 the quadratic form
        // is EXACT. Verify decision values match κ-evaluation to f32
        // rounding on every training point.
        let (model, ds) = poly_model();
        let expanded = expand_poly2(&model).unwrap();
        for r in 0..ds.len() {
            let via_kernel = model.decision_one(ds.x.row(r));
            let (via_form, _) = expanded.decision_one(ds.x.row(r));
            assert!(
                (via_kernel - via_form).abs()
                    < 2e-3 * (1.0 + via_kernel.abs()),
                "row {r}: {via_kernel} vs {via_form}"
            );
        }
    }

    #[test]
    fn closed_form_coefficients() {
        // Two hand-built SVs: check c, w, D against Eqs. 3.14–3.16.
        let (gamma, beta) = (0.5f32, 2.0f32);
        let model = SvmModel::new(
            Kernel::Poly2 { gamma, beta },
            Mat::from_vec(2, 2, vec![1., 0., 0., 1.]).unwrap(),
            vec![0.75, -0.5],
            0.0,
        )
        .unwrap();
        let e = expand_poly2(&model).unwrap();
        // c = β² Σ αy = 4 · 0.25 = 1
        assert!((e.c - 1.0).abs() < 1e-6);
        // v = Xᵀw with wᵢ = 2βγ αᵢyᵢ = 2·(0.75, −0.5)
        assert!((e.v[0] - 1.5).abs() < 1e-6);
        assert!((e.v[1] + 1.0).abs() < 1e-6);
        // M = XᵀDX with Dᵢᵢ = γ²αᵢyᵢ = (0.1875, −0.125) on the diagonal
        assert!((e.m.at(0, 0) - 0.1875).abs() < 1e-6);
        assert!((e.m.at(1, 1) + 0.125).abs() < 1e-6);
        assert_eq!(e.m.at(0, 1), 0.0);
    }

    #[test]
    fn scaling_factor_confined_in_bound() {
        // §3.2: within the bound (and ‖x_M‖ ≥ ‖z‖) the RBF scaling
        // factor lies in (e^{−1/4}, 1].
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..200 {
            let xm_sq = rng.range(0.1, 10.0) as f32;
            let gamma = 1.0 / (4.0 * xm_sq); // at the γ cap for ‖z‖≤‖x_M‖
            let zn_sq = rng.range(0.0, f64::from(xm_sq)) as f32;
            let s = rbf_extra_scaling(gamma, zn_sq);
            assert!(s > MIN_SCALING_IN_BOUND - 1e-9, "s={s}");
            assert!(s <= 1.0);
        }
    }

    #[test]
    fn equivalent_coefficients_fold_exponentials() {
        let ds = synth::two_gaussians(82, 50, 4, 1.5);
        let (model, _) = train_csvc(
            &ds,
            Kernel::Rbf { gamma: 0.3 },
            SmoParams::default(),
        )
        .unwrap();
        let folded = equivalent_poly2_coefficients(&model).unwrap();
        assert_eq!(folded.len(), model.n_sv());
        for i in 0..model.n_sv() {
            // |α·e^{−γ‖x‖²}| ≤ |α| with equality only at ‖x‖ = 0.
            assert!(folded[i].abs() <= model.coef[i].abs() + 1e-7);
            assert_eq!(folded[i].signum(), model.coef[i].signum());
        }
    }

    #[test]
    fn non_poly_rejected() {
        let (model, _) = poly_model();
        assert!(equivalent_poly2_coefficients(&model).is_err());
        let rbf = SvmModel::new(
            Kernel::Rbf { gamma: 0.1 },
            Mat::zeros(1, 2),
            vec![1.0],
            0.0,
        )
        .unwrap();
        assert!(expand_poly2(&rbf).is_err());
    }
}
