//! Build an [`ApproxModel`] from an exact RBF [`SvmModel`] (Eq. 3.8) —
//! the paper's "approximation" stage whose cost is Table 2's t_approx:
//!
//! ```text
//! e_i = exp(−γ‖x_i‖²)
//! c   = Σ coef_i e_i
//! v   = Xᵀ w,          w_i = 2γ  coef_i e_i
//! M   = Xᵀ diag(D) X,  D_i = 2γ² coef_i e_i     (dominant: weighted SYRK)
//! ```
//!
//! The `backend` argument reproduces the paper's LOOPS/BLAS/ATLAS axis
//! for this stage; the XLA backend is driven by [`crate::runtime`].

use crate::linalg::{syrk, vecops, MathBackend};
use crate::svm::{Kernel, SvmModel};
use crate::{approx::ApproxModel, Error, Result};

/// Intermediate weights shared by all backends.
pub struct BuilderWeights {
    pub c: f32,
    /// w_i = 2γ coef_i e_i.
    pub w: Vec<f32>,
    /// D_i = 2γ² coef_i e_i.
    pub d: Vec<f32>,
    pub max_sv_norm_sq: f32,
}

/// Compute (c, w, D, ‖x_M‖²) from the model — O(n_SV · d).
pub fn builder_weights(model: &SvmModel, gamma: f32) -> BuilderWeights {
    let mut c = 0.0f64;
    let n = model.n_sv();
    let mut w = Vec::with_capacity(n);
    let mut d = Vec::with_capacity(n);
    let mut max_norm = 0.0f32;
    for i in 0..n {
        let norm_sq = vecops::norm_sq(model.sv.row(i));
        max_norm = max_norm.max(norm_sq);
        let e = (-gamma * norm_sq).exp();
        let ce = model.coef[i] * e;
        c += f64::from(ce);
        w.push(2.0 * gamma * ce);
        d.push(2.0 * gamma * gamma * ce);
    }
    BuilderWeights { c: c as f32, w, d, max_sv_norm_sq: max_norm }
}

/// Build the approximate model. Fails on non-RBF kernels.
pub fn build_approx_model(
    model: &SvmModel,
    backend: MathBackend,
) -> Result<ApproxModel> {
    let gamma = match model.kernel {
        Kernel::Rbf { gamma } => gamma,
        ref k => {
            return Err(Error::InvalidArg(format!(
                "approximation requires an RBF kernel, got {}",
                k.name()
            )))
        }
    };
    let bw = builder_weights(model, gamma);
    let (v, m) = match backend {
        MathBackend::Loops => (
            syrk::xt_w(&model.sv, &bw.w),
            syrk::syrk_weighted_loops(&model.sv, &bw.d),
        ),
        MathBackend::Blocked => (
            syrk::xt_w(&model.sv, &bw.w),
            syrk::syrk_weighted_blocked(&model.sv, &bw.d),
        ),
        MathBackend::Xla => {
            return Err(Error::InvalidArg(
                "use runtime::Engine::build_approx for the XLA backend".into(),
            ))
        }
    };
    Ok(ApproxModel {
        gamma,
        b: model.b,
        c: bw.c,
        v,
        m,
        max_sv_norm_sq: bw.max_sv_norm_sq,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;
    use crate::data::synth;
    use crate::linalg::Mat;
    use crate::svm::smo::{train_csvc, SmoParams};

    /// Hand-built two-SV model for closed-form verification.
    fn tiny_model(gamma: f32) -> SvmModel {
        SvmModel::new(
            Kernel::Rbf { gamma },
            Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 2.0]).unwrap(),
            vec![0.5, -0.25],
            0.1,
        )
        .unwrap()
    }

    #[test]
    fn closed_form_two_svs() {
        let gamma = 0.3f32;
        let model = tiny_model(gamma);
        let am = build_approx_model(&model, MathBackend::Loops).unwrap();
        let e1 = (-gamma * 1.0f32).exp();
        let e2 = (-gamma * 4.0f32).exp();
        let c = 0.5 * e1 - 0.25 * e2;
        assert!((am.c - c).abs() < 1e-6);
        // v = 2γ (coef1 e1 x1 + coef2 e2 x2)
        let v0 = 2.0 * gamma * 0.5 * e1 * 1.0;
        let v1 = 2.0 * gamma * -0.25 * e2 * 2.0;
        assert!((am.v[0] - v0).abs() < 1e-6);
        assert!((am.v[1] - v1).abs() < 1e-6);
        // M diag: 2γ² (coef1 e1 x1⊗x1 + coef2 e2 x2⊗x2)
        let m00 = 2.0 * gamma * gamma * 0.5 * e1 * 1.0;
        let m11 = 2.0 * gamma * gamma * -0.25 * e2 * 4.0;
        assert!((am.m.at(0, 0) - m00).abs() < 1e-6);
        assert!((am.m.at(1, 1) - m11).abs() < 1e-6);
        assert_eq!(am.m.at(0, 1), 0.0);
        assert_eq!(am.max_sv_norm_sq, 4.0);
        assert_eq!(am.b, model.b);
    }

    #[test]
    fn backends_agree() {
        let ds = synth::two_gaussians(41, 200, 10, 1.2);
        let (model, _) = train_csvc(
            &ds,
            Kernel::Rbf { gamma: 0.3 },
            SmoParams::default(),
        )
        .unwrap();
        let a = build_approx_model(&model, MathBackend::Loops).unwrap();
        let b = build_approx_model(&model, MathBackend::Blocked).unwrap();
        assert!(a.m.max_abs_diff(&b.m) < 1e-4 * (1.0 + a.m.fro_norm() as f32));
        for (x, y) in a.v.iter().zip(&b.v) {
            assert!((x - y).abs() < 1e-4);
        }
        assert!((a.c - b.c).abs() < 1e-5);
    }

    #[test]
    fn approx_tracks_exact_within_bound() {
        // Construct a bound-respecting regime: unit-scaled data and a γ
        // below γ_max = 1/(4‖x_M‖‖z‖_max). Then f̂ ≈ f to a few percent.
        let ds = synth::two_gaussians(42, 300, 8, 2.0);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let gamma = 0.2f32; // < 1/4 since all norms ≈ 1
        let (model, _) = train_csvc(
            &scaled,
            Kernel::Rbf { gamma },
            SmoParams::default(),
        )
        .unwrap();
        let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
        let mut max_rel = 0.0f32;
        let mut scale = 0.0f32;
        for r in 0..scaled.len() {
            let exact = model.decision_one(scaled.x.row(r));
            let (approx, zn) = am.decision_one(scaled.x.row(r));
            assert!(zn <= am.znorm_sq_budget() * 1.01, "bound should hold");
            max_rel = max_rel.max((exact - approx).abs());
            scale = scale.max((exact - model.b).abs());
        }
        assert!(
            max_rel < 0.05 * scale.max(0.1),
            "max abs err {max_rel}, scale {scale}"
        );
    }

    #[test]
    fn non_rbf_rejected() {
        let model = SvmModel::new(
            Kernel::Linear,
            Mat::zeros(1, 2),
            vec![1.0],
            0.0,
        )
        .unwrap();
        assert!(build_approx_model(&model, MathBackend::Loops).is_err());
        assert!(matches!(
            build_approx_model(&tiny_model(0.1), MathBackend::Xla),
            Err(Error::InvalidArg(_))
        ));
    }

    #[test]
    fn property_model_size_independent_of_nsv() {
        // The headline claim: approx model size depends on d only.
        prop_cases!("size-indep-nsv", 4, |rng| {
            let d = 4 + rng.below(8);
            let build = |n: usize, rng: &mut crate::util::Rng| {
                let x = Mat::from_vec(
                    n,
                    d,
                    (0..n * d).map(|_| rng.normal() as f32).collect(),
                )
                .unwrap();
                let coef = (0..n).map(|_| rng.normal() as f32).collect();
                let m = SvmModel::new(
                    Kernel::Rbf { gamma: 0.1 },
                    x,
                    coef,
                    0.0,
                )
                .unwrap();
                build_approx_model(&m, MathBackend::Loops).unwrap()
            };
            let small = build(5, rng);
            let large = build(200, rng);
            assert_eq!(small.dim(), large.dim());
            assert_eq!(small.m.rows(), large.m.rows());
        });
    }
}
