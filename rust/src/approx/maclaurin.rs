//! Second-order Maclaurin series of the exponential (Appendix A):
//! `e^x ≈ 1 + x + x²/2`, with the paper's error constants: the relative
//! error stays below 3.05% for |x| < ½ (Eq. A.2), which is what the
//! validity bound Eq. (3.9)/(3.11) enforces term-wise.

/// The paper's exponent interval half-width (Eq. 3.9: |2γxᵀz| < ½).
pub const EXPONENT_BOUND: f64 = 0.5;

/// Max relative error of the approximation on |x| ≤ ½ (Eq. A.2).
pub const MAX_REL_ERROR_IN_BOUND: f64 = 0.0305;

/// `1 + x + x²/2`.
#[inline]
pub fn maclaurin2(x: f64) -> f64 {
    1.0 + x + 0.5 * x * x
}

/// Absolute relative error `|e^x − (1+x+x²/2)| / e^x` (Figure 1's y).
#[inline]
pub fn rel_error(x: f64) -> f64 {
    (x.exp() - maclaurin2(x)).abs() / x.exp()
}

/// Sample the Figure 1 curve on `[lo, hi]` with `n` points.
/// Returns (x, y) pairs.
pub fn error_curve(lo: f64, hi: f64, n: usize) -> Vec<(f64, f64)> {
    assert!(n >= 2);
    (0..n)
        .map(|i| {
            let x = lo + (hi - lo) * i as f64 / (n - 1) as f64;
            (x, rel_error(x))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_at_zero() {
        assert_eq!(maclaurin2(0.0), 1.0);
        assert_eq!(rel_error(0.0), 0.0);
    }

    #[test]
    fn eq_a2_bound_holds() {
        // Paper Eq. (A.2): |x| < 1/2 ⇒ rel error < 0.0305.
        let curve = error_curve(-EXPONENT_BOUND, EXPONENT_BOUND, 20001);
        let max = curve.iter().map(|&(_, y)| y).fold(0.0, f64::max);
        assert!(max < MAX_REL_ERROR_IN_BOUND, "max={max}");
        // And the bound is tight-ish: the max is attained near x = −½.
        assert!(max > 0.028, "bound should be near-tight, max={max}");
    }

    #[test]
    fn error_explodes_outside_bound() {
        // Figure 1's message: the error grows fast past |x| = ½.
        assert!(rel_error(-2.0) > 0.5);
        assert!(rel_error(2.0) > 0.3);
        assert!(rel_error(-1.0) > rel_error(-0.5));
    }

    #[test]
    fn error_monotone_away_from_zero() {
        let mut prev = 0.0;
        for i in 1..=40 {
            let x = -2.0 * i as f64 / 40.0; // 0 → −2
            let e = rel_error(x);
            assert!(e >= prev, "x={x}");
            prev = e;
        }
    }

    #[test]
    fn curve_shape() {
        let c = error_curve(-2.0, 2.0, 101);
        assert_eq!(c.len(), 101);
        assert_eq!(c[0].0, -2.0);
        assert_eq!(c[100].0, 2.0);
        // Negative side is worse than positive side (e^x in denominator).
        assert!(c[0].1 > c[100].1);
    }
}
