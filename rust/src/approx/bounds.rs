//! Validity bounds (paper §3.1) plus quantization error accounting.
//!
//! Term-wise accuracy needs `|2γ x_iᵀz| < ½` (Eq. 3.9). Cauchy–Schwarz
//! turns that into the checkable `‖x_M‖²‖z‖² < 1/(16γ²)` (Eq. 3.11),
//! giving (a) a pre-training cap `γ_MAX` from data norms and (b) a
//! zero-cost per-instance run-time check (‖z‖² is computed anyway).
//!
//! When a model's payload is quantized (f16/int8 `.arbf` records, see
//! [`crate::registry::quant`]), dequantization perturbs the served
//! coefficients by a *known per-element bound* — [`QuantErrorBound`]
//! (approx path) and [`ExactQuantErr`] (exact path) turn those element
//! bounds into decision-value bounds, and
//! [`QuantErrorBound::drift_budget`] folds the approx-side bound back
//! into the Eq. 3.11 routing budget so a Hybrid router stops trusting
//! the approximation once quantization drift could exceed the
//! configured tolerance.

use crate::data::Dataset;

/// Default cap on the absolute decision drift quantization may add to
/// an approx-routed instance before the Hybrid router escorts it to the
/// exact path (coordinator knob: `CoordinatorBuilder::quant_drift_tol`).
/// Decisions of the models this repo trains are O(1), so 0.25 trades a
/// visible-but-bounded drift ceiling against keeping well-conditioned
/// quantized tenants on the fast path; drop it for margin-critical
/// tenants. Note the escort target of a quantized bundle is itself
/// quantized (its own drift is reported by
/// [`ExactQuantErr::decision_error`], which does not depend on ‖z‖²).
pub const DEFAULT_QUANT_DRIFT_TOL: f32 = 0.25;

/// Multiplicative slack the decision-error bounds carry for the float
/// rounding of the (dequantized) evaluation itself, plus a tiny
/// absolute floor — both far above the 2⁻²⁴-relative reality.
const QUANT_EVAL_SLACK: f32 = 1.001;
const QUANT_EVAL_FLOOR: f32 = 1e-6;

/// Per-element dequantization error bounds of a quantized approx
/// payload: `|Δv_i| ≤ eps_v`, `|ΔM_rc| ≤ eps_m` (scalars `γ, b, c`
/// stay f32, so they contribute nothing), plus the query-side terms of
/// the int8 integer kernels (`linalg::quantblas` quantizes the query
/// row to i16 so all dispatch arms accumulate in exact integer
/// arithmetic): `|Δz_i| ≤ eps_z_rel·‖z‖₂`, weighted by the dequantized
/// coefficient mass `v_abs_sum = Σ|v̂_i|` and
/// `m_abs_sum = Σ_rc|M̂_rc|` (mirrored). f16 payloads keep the query
/// in f32, so their `eps_z_rel` is 0 and the bound reduces to the
/// weight-only form.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantErrorBound {
    pub dim: usize,
    pub eps_v: f32,
    pub eps_m: f32,
    /// Relative per-element query quantization error (0 when the query
    /// is evaluated in f32; `quantblas::Z16_REL_EPS` on int8).
    pub eps_z_rel: f32,
    /// `Σ|v̂_i|` of the dequantized linear term.
    pub v_abs_sum: f32,
    /// `Σ_rc |M̂_rc|` of the dequantized mirrored quadratic term.
    pub m_abs_sum: f32,
}

impl QuantErrorBound {
    /// Absolute decision-error bound for an instance with squared norm
    /// `zn_sq`. Since `e^{−γ‖z‖²} ≤ 1`, Cauchy–Schwarz /
    /// `Σ|z_i| ≤ √d·‖z‖` on the weight errors, and
    /// `|Δz_i| ≤ eps_z = eps_z_rel·‖z‖` on the query error
    /// (`|ẑ_rẑ_c − z_rz_c| ≤ 2‖z‖·eps_z + eps_z²`):
    ///
    /// ```text
    /// |Δf̂(z)| ≤ eps_v·√(d·‖z‖²) + eps_m·d·‖z‖²            (weights)
    ///         + Σ|v̂|·eps_z + Σ|M̂|·(2‖z‖ + eps_z)·eps_z    (query)
    /// ```
    ///
    /// padded by a 0.1% evaluation-rounding slack.
    pub fn decision_error(&self, zn_sq: f32) -> f32 {
        let zn = zn_sq.max(0.0);
        let s = (self.dim as f32 * zn).sqrt();
        let weight = self.eps_v * s + self.eps_m * s * s;
        let eps_z = self.eps_z_rel * zn.sqrt();
        let query = self.v_abs_sum * eps_z
            + self.m_abs_sum * (2.0 * zn.sqrt() + eps_z) * eps_z;
        (weight + query) * QUANT_EVAL_SLACK + QUANT_EVAL_FLOOR
    }

    /// Largest ‖z‖² whose [`QuantErrorBound::decision_error`] stays
    /// within `tol` — the quantization term the serving router
    /// intersects with the Eq. 3.11 budget. Infinite when the payload
    /// carries no error (or `tol` is infinite).
    pub fn drift_budget(&self, tol: f32) -> f32 {
        if !tol.is_finite() {
            return f32::INFINITY;
        }
        let tol = (tol - QUANT_EVAL_FLOOR) / QUANT_EVAL_SLACK;
        if tol <= 0.0 {
            return 0.0;
        }
        let d = self.dim as f32;
        // decision_error(zn) = a·t² + b·t with t = √‖z‖² — the weight
        // terms grouped with the query terms by power of t.
        let a = self.eps_m * d
            + self.m_abs_sum * self.eps_z_rel * (2.0 + self.eps_z_rel);
        let b = self.eps_v * d.sqrt() + self.v_abs_sum * self.eps_z_rel;
        let t = if a <= 0.0 && b <= 0.0 {
            return f32::INFINITY;
        } else if a <= 0.0 {
            tol / b
        } else {
            (-b + (b * b + 4.0 * a * tol).sqrt()) / (2.0 * a)
        };
        t * t
    }
}

/// Dequantization error metadata of a quantized *exact* (RBF) model:
/// `|Δcoef_i| ≤ eps_coef`, per-element SV error ≤ `eps_sv`, and (int8
/// payloads only) the relative per-element error `eps_z_rel` of the
/// i16-quantized query the integer kernels evaluate against.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExactQuantErr {
    pub n_sv: usize,
    pub dim: usize,
    /// RBF γ (NaN for non-RBF kernels — the bound is then unavailable).
    pub gamma: f32,
    /// Σ|coef_i| of the dequantized coefficients.
    pub coef_abs_sum: f32,
    pub eps_coef: f32,
    pub eps_sv: f32,
    /// Relative per-element query quantization error (0 when the query
    /// is evaluated in f32; `quantblas::Z16_REL_EPS` on int8).
    pub eps_z_rel: f32,
}

impl ExactQuantErr {
    /// Absolute decision-error bound of the quantized exact RBF model's
    /// *weight* perturbation, independent of the instance: with
    /// `K ∈ (0, 1]` and the RBF kernel globally `√(2γ/e)`-Lipschitz in
    /// its SV argument,
    ///
    /// ```text
    /// |Δf(z)| ≤ n_SV·eps_coef
    ///         + (Σ|coef_i| + n_SV·eps_coef)·√(2γ/e)·√d·eps_sv
    /// ```
    ///
    /// Returns ∞ for non-RBF kernels (no bound reported). For int8
    /// payloads the served bound also carries a query-quantization
    /// term that grows with ‖z‖ — use
    /// [`ExactQuantErr::decision_error_at`]; this z-independent form is
    /// what the CLI summarizes.
    pub fn decision_error(&self) -> f32 {
        if !self.gamma.is_finite() || self.gamma < 0.0 {
            return f32::INFINITY;
        }
        let n = self.n_sv as f32;
        let lipschitz = (2.0 * self.gamma / std::f32::consts::E).sqrt();
        let sv_term = (self.coef_abs_sum + n * self.eps_coef)
            * lipschitz
            * (self.dim as f32).sqrt()
            * self.eps_sv;
        (n * self.eps_coef + sv_term) * QUANT_EVAL_SLACK + QUANT_EVAL_FLOOR
    }

    /// Full decision-error bound for an instance with squared norm
    /// `zn_sq`: [`ExactQuantErr::decision_error`] plus the
    /// query-quantization term — the same Lipschitz argument applied
    /// to `‖Δz‖₂ ≤ √d·eps_z_rel·‖z‖₂` (the int8 kernels evaluate
    /// `K(x̂, ẑ)` with the quantized query's own norm, so the
    /// perturbation really is a shift of the kernel's z argument).
    pub fn decision_error_at(&self, zn_sq: f32) -> f32 {
        let base = self.decision_error();
        if !base.is_finite() || self.eps_z_rel <= 0.0 {
            return base;
        }
        let n = self.n_sv as f32;
        let lipschitz = (2.0 * self.gamma / std::f32::consts::E).sqrt();
        let z_term = (self.coef_abs_sum + n * self.eps_coef)
            * lipschitz
            * (self.dim as f32).sqrt()
            * self.eps_z_rel
            * zn_sq.max(0.0).sqrt();
        base + z_term * QUANT_EVAL_SLACK
    }
}

/// Pre-training γ cap for a dataset (paper: "report an upper bound for γ
/// for a given data set prior to training"): both the future SVs and
/// the future test points are bounded by the max data norm, so
/// `γ_MAX = 1 / (4 · max‖x‖²)`. Slightly over-conservative because the
/// max-norm instance need not become a support vector (§3.1).
pub fn gamma_max_for_data(ds: &Dataset) -> f32 {
    let m = ds.max_norm_sq();
    if m <= 0.0 {
        f32::INFINITY
    } else {
        1.0 / (4.0 * m)
    }
}

/// γ cap given a trained model and an expected max test-instance norm:
/// `γ_MAX = 1/(4‖x_M‖‖z‖_max)` (Eq. 3.11 solved for γ).
pub fn gamma_max_for_model(max_sv_norm_sq: f32, max_z_norm_sq: f32) -> f32 {
    let prod = (max_sv_norm_sq * max_z_norm_sq).sqrt();
    if prod <= 0.0 {
        f32::INFINITY
    } else {
        1.0 / (4.0 * prod)
    }
}

/// Per-instance run-time check (Eq. 3.11): valid iff
/// `zn_sq < 1/(16 γ² ‖x_M‖²)`.
#[inline]
pub fn instance_in_bound(zn_sq: f32, znorm_sq_budget: f32) -> bool {
    zn_sq < znorm_sq_budget
}

/// Summary of bound adherence over a batch / dataset (drives Table 1's
/// interpretation and the A2 routing ablation).
#[derive(Clone, Debug)]
pub struct BoundReport {
    pub gamma: f32,
    pub gamma_max: f32,
    /// γ/γ_MAX — >1 means guarantees are abandoned (paper §4.2).
    pub gamma_ratio: f32,
    pub n_total: usize,
    pub n_in_bound: usize,
}

impl BoundReport {
    /// Evaluate bound adherence of every instance in `ds` against a
    /// model's stored ‖x_M‖² and γ.
    pub fn evaluate(
        ds: &Dataset,
        gamma: f32,
        max_sv_norm_sq: f32,
    ) -> BoundReport {
        let budget = 1.0 / (16.0 * gamma * gamma * max_sv_norm_sq);
        let norms = ds.x.row_norms_sq();
        let n_in = norms.iter().filter(|&&n| instance_in_bound(n, budget)).count();
        let gamma_max =
            gamma_max_for_model(max_sv_norm_sq, norms.iter().copied().fold(0.0, f32::max));
        BoundReport {
            gamma,
            gamma_max,
            gamma_ratio: gamma / gamma_max,
            n_total: ds.len(),
            n_in_bound: n_in,
        }
    }

    pub fn fraction_in_bound(&self) -> f64 {
        self.n_in_bound as f64 / self.n_total.max(1) as f64
    }

    /// All instances guaranteed term-wise ≤3.05% relative error.
    pub fn fully_valid(&self) -> bool {
        self.n_in_bound == self.n_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;
    use crate::data::synth;
    use crate::linalg::Mat;

    #[test]
    fn gamma_max_formula() {
        let ds = Dataset::new(
            Mat::from_vec(2, 2, vec![3.0, 4.0, 0.0, 1.0]).unwrap(),
            vec![1.0, -1.0],
        )
        .unwrap();
        // max norm² = 25 ⇒ γ_max = 1/100.
        assert!((gamma_max_for_data(&ds) - 0.01).abs() < 1e-8);
    }

    #[test]
    fn gamma_max_consistent_with_budget() {
        // At γ = γ_max exactly, the worst instance sits on the boundary.
        let max_sv = 2.0f32;
        let max_z = 3.0f32;
        let gmax = gamma_max_for_model(max_sv, max_z);
        let budget = 1.0 / (16.0 * gmax * gmax * max_sv);
        assert!((budget - max_z).abs() < 1e-4);
    }

    #[test]
    fn unit_norm_data_gamma_max_quarter() {
        let ds = synth::two_gaussians(51, 100, 5, 1.0);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let g = gamma_max_for_data(&scaled);
        assert!((g - 0.25).abs() < 1e-3, "g={g}");
    }

    #[test]
    fn report_counts() {
        let ds = Dataset::new(
            Mat::from_vec(3, 1, vec![0.1, 0.5, 10.0]).unwrap(),
            vec![1.0, 1.0, -1.0],
        )
        .unwrap();
        // γ=0.5, ‖x_M‖²=1 ⇒ budget = 1/(16·0.25·1) = 0.25.
        let r = BoundReport::evaluate(&ds, 0.5, 1.0);
        // norms² = [0.01, 0.25, 100] ⇒ only the first is < 0.25.
        assert_eq!(r.n_in_bound, 1);
        assert!(!r.fully_valid());
        assert!((r.fraction_in_bound() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn in_bound_instances_have_small_term_error() {
        // The end-to-end guarantee: respecting Eq. 3.11 keeps each
        // exponential's Maclaurin error under 3.05%.
        prop_cases!("bound-implies-accuracy", 16, |rng| {
            let d = 1 + rng.below(10);
            let x: Vec<f32> =
                (0..d).map(|_| rng.normal() as f32).collect();
            let z: Vec<f32> =
                (0..d).map(|_| rng.normal() as f32).collect();
            let xn = crate::linalg::vecops::norm_sq(&x);
            let zn = crate::linalg::vecops::norm_sq(&z);
            let gamma = rng.range(1e-3, 1.0) as f32;
            let budget = 1.0 / (16.0 * gamma * gamma * xn);
            if instance_in_bound(zn, budget) {
                let u = f64::from(
                    2.0 * gamma * crate::linalg::vecops::dot(&x, &z),
                );
                assert!(u.abs() <= 0.5 + 1e-5);
                let rel = crate::approx::maclaurin::rel_error(u);
                assert!(rel < 0.0305, "rel={rel} u={u}");
            }
        });
    }

    #[test]
    fn zero_data_infinite_gamma() {
        let ds = Dataset::new(Mat::zeros(2, 2), vec![1.0, -1.0]).unwrap();
        assert!(gamma_max_for_data(&ds).is_infinite());
    }

    /// A weight-only bound (f16-style: query stays f32).
    fn weight_only(dim: usize, eps_v: f32, eps_m: f32) -> QuantErrorBound {
        QuantErrorBound {
            dim,
            eps_v,
            eps_m,
            eps_z_rel: 0.0,
            v_abs_sum: 0.0,
            m_abs_sum: 0.0,
        }
    }

    #[test]
    fn quant_drift_budget_inverts_decision_error() {
        let with_query = QuantErrorBound {
            eps_z_rel: 1.6e-5,
            v_abs_sum: 3.0,
            m_abs_sum: 12.0,
            ..weight_only(8, 4e-3, 1.5e-3)
        };
        for q in [weight_only(8, 4e-3, 1.5e-3), with_query] {
            for tol in [0.01f32, 0.05, 0.25, 1.0] {
                let zn = q.drift_budget(tol);
                assert!(zn.is_finite() && zn > 0.0, "tol={tol}: zn={zn}");
                // At the budget, the error sits on the tolerance
                // (within float slop); just inside it stays below.
                let err = q.decision_error(zn);
                assert!(
                    (err - tol).abs() < 1e-3 * tol.max(1.0),
                    "{err} vs {tol}"
                );
                assert!(q.decision_error(zn * 0.99) < tol);
            }
            // Monotone in the tolerance.
            assert!(q.drift_budget(0.01) < q.drift_budget(0.25));
        }
        // Query terms only tighten the budget.
        assert!(
            with_query.drift_budget(0.25)
                <= weight_only(8, 4e-3, 1.5e-3).drift_budget(0.25)
        );
    }

    #[test]
    fn quant_drift_budget_degenerate_cases() {
        let none = weight_only(4, 0.0, 0.0);
        assert!(none.drift_budget(0.1).is_infinite());
        assert_eq!(none.decision_error(10.0), 1e-6);
        let v_only = weight_only(4, 1e-3, 0.0);
        let zn = v_only.drift_budget(0.1);
        assert!(zn.is_finite());
        assert!(v_only.decision_error(zn) <= 0.1 + 1e-5);
        // A tolerance below the floor yields a zero budget, and an
        // infinite tolerance never constrains.
        assert_eq!(v_only.drift_budget(0.0), 0.0);
        assert!(v_only.drift_budget(f32::INFINITY).is_infinite());
        // A pure query-side bound (exactly stored weights) still
        // inverts through the linear term.
        let z_only = QuantErrorBound {
            eps_z_rel: 1.6e-5,
            v_abs_sum: 2.0,
            m_abs_sum: 0.0,
            ..weight_only(4, 0.0, 0.0)
        };
        let zn = z_only.drift_budget(0.1);
        assert!(zn.is_finite());
        assert!(z_only.decision_error(zn) <= 0.1 + 1e-5);
    }

    #[test]
    fn exact_quant_error_shape() {
        let e = ExactQuantErr {
            n_sv: 10,
            dim: 4,
            gamma: 0.5,
            coef_abs_sum: 5.0,
            eps_coef: 1e-3,
            eps_sv: 2e-3,
            eps_z_rel: 0.0,
        };
        let bound = e.decision_error();
        // n·eps_coef = 0.01; sv term = (5 + 0.01)·√(1/e)·2·2e-3 ≈ 0.0122.
        assert!(bound > 0.02 && bound < 0.03, "{bound}");
        // Without a quantized query the z-aware bound degenerates.
        assert_eq!(e.decision_error_at(100.0), bound);
        // With one it grows with ‖z‖, slowly (i16 query).
        let q = ExactQuantErr { eps_z_rel: 1.6e-5, ..e };
        let at_zero = q.decision_error_at(0.0);
        let at_ten = q.decision_error_at(100.0);
        assert!(at_zero >= bound && at_ten > at_zero, "{at_zero} {at_ten}");
        assert!(at_ten < bound * 1.2, "query term should be marginal");
        // Non-RBF → no bound, also through the z-aware form.
        let lin = ExactQuantErr { gamma: f32::NAN, ..e };
        assert!(lin.decision_error().is_infinite());
        assert!(lin.decision_error_at(4.0).is_infinite());
    }
}
