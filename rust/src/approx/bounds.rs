//! Validity bounds (paper §3.1).
//!
//! Term-wise accuracy needs `|2γ x_iᵀz| < ½` (Eq. 3.9). Cauchy–Schwarz
//! turns that into the checkable `‖x_M‖²‖z‖² < 1/(16γ²)` (Eq. 3.11),
//! giving (a) a pre-training cap `γ_MAX` from data norms and (b) a
//! zero-cost per-instance run-time check (‖z‖² is computed anyway).

use crate::data::Dataset;

/// Pre-training γ cap for a dataset (paper: "report an upper bound for γ
/// for a given data set prior to training"): both the future SVs and
/// the future test points are bounded by the max data norm, so
/// `γ_MAX = 1 / (4 · max‖x‖²)`. Slightly over-conservative because the
/// max-norm instance need not become a support vector (§3.1).
pub fn gamma_max_for_data(ds: &Dataset) -> f32 {
    let m = ds.max_norm_sq();
    if m <= 0.0 {
        f32::INFINITY
    } else {
        1.0 / (4.0 * m)
    }
}

/// γ cap given a trained model and an expected max test-instance norm:
/// `γ_MAX = 1/(4‖x_M‖‖z‖_max)` (Eq. 3.11 solved for γ).
pub fn gamma_max_for_model(max_sv_norm_sq: f32, max_z_norm_sq: f32) -> f32 {
    let prod = (max_sv_norm_sq * max_z_norm_sq).sqrt();
    if prod <= 0.0 {
        f32::INFINITY
    } else {
        1.0 / (4.0 * prod)
    }
}

/// Per-instance run-time check (Eq. 3.11): valid iff
/// `zn_sq < 1/(16 γ² ‖x_M‖²)`.
#[inline]
pub fn instance_in_bound(zn_sq: f32, znorm_sq_budget: f32) -> bool {
    zn_sq < znorm_sq_budget
}

/// Summary of bound adherence over a batch / dataset (drives Table 1's
/// interpretation and the A2 routing ablation).
#[derive(Clone, Debug)]
pub struct BoundReport {
    pub gamma: f32,
    pub gamma_max: f32,
    /// γ/γ_MAX — >1 means guarantees are abandoned (paper §4.2).
    pub gamma_ratio: f32,
    pub n_total: usize,
    pub n_in_bound: usize,
}

impl BoundReport {
    /// Evaluate bound adherence of every instance in `ds` against a
    /// model's stored ‖x_M‖² and γ.
    pub fn evaluate(
        ds: &Dataset,
        gamma: f32,
        max_sv_norm_sq: f32,
    ) -> BoundReport {
        let budget = 1.0 / (16.0 * gamma * gamma * max_sv_norm_sq);
        let norms = ds.x.row_norms_sq();
        let n_in = norms.iter().filter(|&&n| instance_in_bound(n, budget)).count();
        let gamma_max =
            gamma_max_for_model(max_sv_norm_sq, norms.iter().copied().fold(0.0, f32::max));
        BoundReport {
            gamma,
            gamma_max,
            gamma_ratio: gamma / gamma_max,
            n_total: ds.len(),
            n_in_bound: n_in,
        }
    }

    pub fn fraction_in_bound(&self) -> f64 {
        self.n_in_bound as f64 / self.n_total.max(1) as f64
    }

    /// All instances guaranteed term-wise ≤3.05% relative error.
    pub fn fully_valid(&self) -> bool {
        self.n_in_bound == self.n_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;
    use crate::data::synth;
    use crate::linalg::Mat;

    #[test]
    fn gamma_max_formula() {
        let ds = Dataset::new(
            Mat::from_vec(2, 2, vec![3.0, 4.0, 0.0, 1.0]).unwrap(),
            vec![1.0, -1.0],
        )
        .unwrap();
        // max norm² = 25 ⇒ γ_max = 1/100.
        assert!((gamma_max_for_data(&ds) - 0.01).abs() < 1e-8);
    }

    #[test]
    fn gamma_max_consistent_with_budget() {
        // At γ = γ_max exactly, the worst instance sits on the boundary.
        let max_sv = 2.0f32;
        let max_z = 3.0f32;
        let gmax = gamma_max_for_model(max_sv, max_z);
        let budget = 1.0 / (16.0 * gmax * gmax * max_sv);
        assert!((budget - max_z).abs() < 1e-4);
    }

    #[test]
    fn unit_norm_data_gamma_max_quarter() {
        let ds = synth::two_gaussians(51, 100, 5, 1.0);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let g = gamma_max_for_data(&scaled);
        assert!((g - 0.25).abs() < 1e-3, "g={g}");
    }

    #[test]
    fn report_counts() {
        let ds = Dataset::new(
            Mat::from_vec(3, 1, vec![0.1, 0.5, 10.0]).unwrap(),
            vec![1.0, 1.0, -1.0],
        )
        .unwrap();
        // γ=0.5, ‖x_M‖²=1 ⇒ budget = 1/(16·0.25·1) = 0.25.
        let r = BoundReport::evaluate(&ds, 0.5, 1.0);
        // norms² = [0.01, 0.25, 100] ⇒ only the first is < 0.25.
        assert_eq!(r.n_in_bound, 1);
        assert!(!r.fully_valid());
        assert!((r.fraction_in_bound() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn in_bound_instances_have_small_term_error() {
        // The end-to-end guarantee: respecting Eq. 3.11 keeps each
        // exponential's Maclaurin error under 3.05%.
        prop_cases!("bound-implies-accuracy", 16, |rng| {
            let d = 1 + rng.below(10);
            let x: Vec<f32> =
                (0..d).map(|_| rng.normal() as f32).collect();
            let z: Vec<f32> =
                (0..d).map(|_| rng.normal() as f32).collect();
            let xn = crate::linalg::vecops::norm_sq(&x);
            let zn = crate::linalg::vecops::norm_sq(&z);
            let gamma = rng.range(1e-3, 1.0) as f32;
            let budget = 1.0 / (16.0 * gamma * gamma * xn);
            if instance_in_bound(zn, budget) {
                let u = f64::from(
                    2.0 * gamma * crate::linalg::vecops::dot(&x, &z),
                );
                assert!(u.abs() <= 0.5 + 1e-5);
                let rel = crate::approx::maclaurin::rel_error(u);
                assert!(rel < 0.0305, "rel={rel} u={u}");
            }
        });
    }

    #[test]
    fn zero_data_infinite_gamma() {
        let ds = Dataset::new(Mat::zeros(2, 2), vec![1.0, -1.0]).unwrap();
        assert!(gamma_max_for_data(&ds).is_infinite());
    }
}
