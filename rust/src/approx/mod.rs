//! The paper's contribution: second-order Maclaurin approximation of
//! RBF-kernel decision functions (§3) with its validity bounds (§3.1),
//! the builder that turns an exact [`crate::svm::SvmModel`] into an
//! [`ApproxModel`] (Eq. 3.8), compressed-model I/O (Table 3), and
//! error-analysis tooling (Table 1's diff column + Figure 1).

#![forbid(unsafe_code)]

pub mod bounds;
pub mod builder;
pub mod error_analysis;
pub mod maclaurin;
pub mod model;
pub mod poly2_equiv;
pub mod rff;

pub use bounds::{
    gamma_max_for_data, BoundReport, ExactQuantErr, QuantErrorBound,
    DEFAULT_QUANT_DRIFT_TOL,
};
pub use builder::build_approx_model;
pub use model::ApproxModel;
pub use rff::RffModel;
