//! The approximated model (Eq. 3.8): three scalars `(b, c, γ)`, a dense
//! vector `v ∈ ℝᵈ`, a dense symmetric matrix `M ∈ ℝᵈˣᵈ` and the stored
//! `‖x_M‖²` that powers the zero-cost run-time bound check (Eq. 3.11).
//! Text I/O mirrors the exact model's text format so Table 3's size
//! comparison is apples-to-apples.

use std::path::Path;

use crate::data::libsvm_format::fmt_f32;
use crate::linalg::{quadform, vecops, Mat, MathBackend};
use crate::{Error, Result};

/// Approximated RBF-SVM model: f̂(z) = e^{−γ‖z‖²}(c + vᵀz + zᵀMz) + b.
#[derive(Clone, Debug)]
pub struct ApproxModel {
    pub gamma: f32,
    pub b: f32,
    pub c: f32,
    pub v: Vec<f32>,
    pub m: Mat,
    /// ‖x_M‖²: max squared SV norm of the source model (Eq. 3.11).
    pub max_sv_norm_sq: f32,
}

impl ApproxModel {
    pub fn dim(&self) -> usize {
        self.v.len()
    }

    /// Shared validation behind every codec (text and `.arbf` binary):
    /// shapes must agree and every parameter must be finite — a NaN/Inf
    /// smuggled through a model file would silently poison all served
    /// decisions. Returns a human-readable defect description.
    pub fn check_finite(&self) -> std::result::Result<(), String> {
        let d = self.v.len();
        if self.m.rows() != d || self.m.cols() != d {
            return Err(format!(
                "M is {}x{} but v has dim {d}",
                self.m.rows(),
                self.m.cols()
            ));
        }
        for (name, val) in [
            ("gamma", self.gamma),
            ("b", self.b),
            ("c", self.c),
            ("max_sv_norm_sq", self.max_sv_norm_sq),
        ] {
            if !val.is_finite() {
                return Err(format!("non-finite {name}: {val}"));
            }
        }
        if self.max_sv_norm_sq < 0.0 {
            return Err(format!(
                "negative max_sv_norm_sq: {}",
                self.max_sv_norm_sq
            ));
        }
        if let Some(i) = self.v.iter().position(|x| !x.is_finite()) {
            return Err(format!("non-finite v[{i}]"));
        }
        if let Some(i) = self.m.as_slice().iter().position(|x| !x.is_finite())
        {
            return Err(format!("non-finite M entry (flat index {i})"));
        }
        Ok(())
    }

    /// The run-time bound threshold on ‖z‖²: the approximation is
    /// guaranteed term-wise accurate iff `‖z‖² < 1/(16 γ² ‖x_M‖²)`.
    pub fn znorm_sq_budget(&self) -> f32 {
        1.0 / (16.0 * self.gamma * self.gamma * self.max_sv_norm_sq)
    }

    /// Decision value + squared norm for one instance.
    /// O(d²), SIMD-on evaluators (symmetric quadform).
    pub fn decision_one(&self, z: &[f32]) -> (f32, f32) {
        debug_assert_eq!(z.len(), self.dim());
        let zn = vecops::norm_sq(z);
        let quad = quadform::quadform_symmetric(&self.m, z);
        let lin = vecops::dot(&self.v, z);
        ((-self.gamma * zn).exp() * (self.c + lin + quad) + self.b, zn)
    }

    /// Scalar-evaluator variant (the paper's SIMD-off configuration).
    pub fn decision_one_scalar(&self, z: &[f32]) -> (f32, f32) {
        let zn = vecops::dot_scalar(z, z);
        let quad = quadform::quadform_scalar(&self.m, z);
        let lin = vecops::dot_scalar(&self.v, z);
        ((-self.gamma * zn).exp() * (self.c + lin + quad) + self.b, zn)
    }

    /// Batched decisions. Returns (decisions, squared norms).
    pub fn decision_batch(
        &self,
        z: &Mat,
        backend: MathBackend,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if z.cols() != self.dim() {
            return Err(Error::Shape(format!(
                "batch dim {} vs model dim {}",
                z.cols(),
                self.dim()
            )));
        }
        match backend {
            MathBackend::Loops => Ok((0..z.rows())
                .map(|r| self.decision_one_scalar(z.row(r)))
                .fold((Vec::new(), Vec::new()), |mut acc, (d, n)| {
                    acc.0.push(d);
                    acc.1.push(n);
                    acc
                })),
            MathBackend::Blocked => {
                // Batched: Z·M GEMM + fused row ops (TPU-shaped path).
                let quads = quadform::quadform_batch(&self.m, z);
                let mut dec = Vec::with_capacity(z.rows());
                let mut norms = Vec::with_capacity(z.rows());
                for r in 0..z.rows() {
                    let zr = z.row(r);
                    let zn = vecops::norm_sq(zr);
                    let lin = vecops::dot(&self.v, zr);
                    dec.push(
                        (-self.gamma * zn).exp() * (self.c + lin + quads[r])
                            + self.b,
                    );
                    norms.push(zn);
                }
                Ok((dec, norms))
            }
            MathBackend::Xla => Err(Error::InvalidArg(
                "use runtime::Engine for the XLA backend".into(),
            )),
        }
    }

    /// Text encoding (Table 3's "approx" column measures this).
    pub fn to_text(&self) -> String {
        let d = self.dim();
        let mut out = String::new();
        out.push_str("approx_type maclaurin2_rbf\n");
        out.push_str(&format!("d {d}\n"));
        out.push_str(&format!("gamma {}\n", fmt_f32(self.gamma)));
        out.push_str(&format!("b {}\n", fmt_f32(self.b)));
        out.push_str(&format!("c {}\n", fmt_f32(self.c)));
        out.push_str(&format!(
            "max_sv_norm_sq {}\n",
            fmt_f32(self.max_sv_norm_sq)
        ));
        out.push_str("v\n");
        let vs: Vec<String> = self.v.iter().map(|&x| fmt_f32(x)).collect();
        out.push_str(&vs.join(" "));
        out.push('\n');
        // M is symmetric: store the upper triangle row-wise, like the
        // paper's implementation stores a packed symmetric matrix.
        out.push_str("M upper\n");
        for r in 0..d {
            let row: Vec<String> =
                (r..d).map(|c| fmt_f32(self.m.at(r, c))).collect();
            out.push_str(&row.join(" "));
            out.push('\n');
        }
        out
    }

    pub fn text_size_bytes(&self) -> usize {
        self.to_text().len()
    }

    pub fn from_text(text: &str) -> Result<ApproxModel> {
        let mut lines = text.lines();
        let mut d = 0usize;
        let mut gamma = None;
        let mut b = None;
        let mut c = None;
        let mut max_norm = None;
        loop {
            let line = lines
                .next()
                .ok_or_else(|| Error::Parse("truncated approx model".into()))?
                .trim();
            if line == "v" {
                break;
            }
            let mut it = line.split_whitespace();
            match it.next() {
                Some("approx_type") => {
                    let t = it.next().unwrap_or("");
                    if t != "maclaurin2_rbf" {
                        return Err(Error::Parse(format!(
                            "unknown approx_type '{t}'"
                        )));
                    }
                }
                Some("d") => {
                    d = it
                        .next()
                        .and_then(|s| s.parse().ok())
                        .ok_or_else(|| Error::Parse("bad d".into()))?
                }
                Some("gamma") => gamma = parse_f32(it.next()),
                Some("b") => b = parse_f32(it.next()),
                Some("c") => c = parse_f32(it.next()),
                Some("max_sv_norm_sq") => max_norm = parse_f32(it.next()),
                Some(other) => {
                    return Err(Error::Parse(format!(
                        "unknown approx header '{other}'"
                    )))
                }
                None => {}
            }
        }
        if d == 0 {
            return Err(Error::Parse("missing d".into()));
        }
        let v: Vec<f32> = lines
            .next()
            .ok_or_else(|| Error::Parse("missing v".into()))?
            .split_whitespace()
            .map(|s| s.parse::<f32>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|_| Error::Parse("bad v".into()))?;
        if v.len() != d {
            return Err(Error::Parse(format!("v has {} != d", v.len())));
        }
        let header = lines.next().unwrap_or("").trim();
        if header != "M upper" {
            return Err(Error::Parse("missing 'M upper' header".into()));
        }
        let mut m = Mat::zeros(d, d);
        for r in 0..d {
            let row = lines
                .next()
                .ok_or_else(|| Error::Parse("truncated M".into()))?;
            let vals: Vec<f32> = row
                .split_whitespace()
                .map(|s| s.parse::<f32>())
                .collect::<std::result::Result<_, _>>()
                .map_err(|_| Error::Parse("bad M row".into()))?;
            if vals.len() != d - r {
                return Err(Error::Parse(format!(
                    "M row {r}: {} values, expected {}",
                    vals.len(),
                    d - r
                )));
            }
            for (k, &val) in vals.iter().enumerate() {
                *m.at_mut(r, r + k) = val;
                *m.at_mut(r + k, r) = val;
            }
        }
        let model = ApproxModel {
            gamma: gamma.ok_or_else(|| Error::Parse("missing gamma".into()))?,
            b: b.ok_or_else(|| Error::Parse("missing b".into()))?,
            c: c.ok_or_else(|| Error::Parse("missing c".into()))?,
            v,
            m,
            max_sv_norm_sq: max_norm
                .ok_or_else(|| Error::Parse("missing max_sv_norm_sq".into()))?,
        };
        // Rust's f32 parser accepts "nan"/"inf"; reject them here so a
        // damaged model file cannot silently poison every decision.
        model.check_finite().map_err(Error::Parse)?;
        Ok(model)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ApproxModel> {
        ApproxModel::from_text(&std::fs::read_to_string(path)?)
    }
}

fn parse_f32(tok: Option<&str>) -> Option<f32> {
    tok.and_then(|s| s.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ApproxModel {
        ApproxModel {
            gamma: 0.1,
            b: -0.2,
            c: 0.5,
            v: vec![1.0, -2.0],
            m: Mat::from_vec(2, 2, vec![0.5, 0.25, 0.25, -0.75]).unwrap(),
            max_sv_norm_sq: 4.0,
        }
    }

    #[test]
    fn decision_matches_formula() {
        let m = toy();
        let z = [0.3f32, -0.7];
        let zn = 0.09 + 0.49;
        let lin = 0.3 - 2.0 * -0.7;
        let quad = 0.5 * 0.09
            + 2.0 * 0.25 * 0.3 * -0.7
            + -0.75 * 0.49;
        let want = (-0.1f32 * zn).exp() * (0.5 + lin + quad) - 0.2;
        let (got, got_n) = m.decision_one(&z);
        assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        assert!((got_n - zn).abs() < 1e-6);
        let (got_s, _) = m.decision_one_scalar(&z);
        assert!((got_s - want).abs() < 1e-5);
    }

    #[test]
    fn budget_formula() {
        let m = toy();
        // 1/(16 · 0.01 · 4) = 1.5625
        assert!((m.znorm_sq_budget() - 1.5625).abs() < 1e-6);
    }

    #[test]
    fn text_roundtrip() {
        let m = toy();
        let back = ApproxModel::from_text(&m.to_text()).unwrap();
        assert_eq!(back.v, m.v);
        assert_eq!(back.m.max_abs_diff(&m.m), 0.0);
        assert_eq!(back.gamma, m.gamma);
        assert_eq!(back.b, m.b);
        assert_eq!(back.c, m.c);
        assert_eq!(back.max_sv_norm_sq, m.max_sv_norm_sq);
    }

    #[test]
    fn batch_matches_single() {
        let m = toy();
        let z = Mat::from_vec(3, 2, vec![0.1, 0.2, -1.0, 0.5, 0.0, 0.0])
            .unwrap();
        for backend in [MathBackend::Loops, MathBackend::Blocked] {
            let (dec, norms) = m.decision_batch(&z, backend).unwrap();
            for r in 0..3 {
                let (d1, n1) = m.decision_one(z.row(r));
                assert!((dec[r] - d1).abs() < 1e-4, "{backend:?} row {r}");
                assert!((norms[r] - n1).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn corrupt_text_rejected() {
        assert!(ApproxModel::from_text("garbage").is_err());
        let m = toy();
        let text = m.to_text().replace("M upper", "M full");
        assert!(ApproxModel::from_text(&text).is_err());
    }

    #[test]
    fn non_finite_text_rejected() {
        // `"nan".parse::<f32>()` succeeds, so the codec must check.
        let m = toy();
        for (field, bad) in
            [("gamma 0.1", "gamma nan"), ("b -0.2", "b inf"), ("c 0.5", "c -inf")]
        {
            let text = m.to_text().replace(field, bad);
            let err = ApproxModel::from_text(&text).unwrap_err();
            assert!(
                matches!(err, Error::Parse(ref msg) if msg.contains("non-finite")),
                "{bad}: {err}"
            );
        }
        let text = m.to_text().replace("1 -2", "1 nan");
        assert!(ApproxModel::from_text(&text).is_err());
    }

    #[test]
    fn check_finite_catches_shape_drift() {
        let mut m = toy();
        m.v.push(0.0);
        assert!(m.check_finite().is_err());
    }
}
