//! Error analysis between exact and approximated models: Table 1's
//! "diff (%)" column (label disagreements), decision-value error
//! distributions, and the per-term exponent histogram that explains
//! *why* a configuration is or isn't within bounds.

use crate::approx::ApproxModel;
use crate::data::Dataset;
use crate::linalg::MathBackend;
use crate::svm::predict::{labels_from_decisions, ExactPredictor};
use crate::svm::SvmModel;
use crate::util::stats::{accuracy, label_diff_fraction, Summary};
use crate::Result;

/// Comparison of an exact model vs its approximation on a dataset.
#[derive(Clone, Debug)]
pub struct ErrorReport {
    /// Accuracy of the exact model against ground truth.
    pub exact_acc: f64,
    /// Accuracy of the approximated model against ground truth.
    pub approx_acc: f64,
    /// Fraction of labels that differ between the two (Table 1 "diff").
    pub label_diff: f64,
    /// Summary of |f(z) − f̂(z)| over the dataset.
    pub abs_err: Summary,
    /// Fraction of instances satisfying the run-time bound (Eq. 3.11).
    pub in_bound_fraction: f64,
}

/// Compare exact vs approximated decisions over `ds`.
pub fn compare(
    model: &SvmModel,
    am: &ApproxModel,
    ds: &Dataset,
) -> Result<ErrorReport> {
    let exact = ExactPredictor::new(model, MathBackend::Blocked)?
        .decision_batch(&ds.x)?;
    let (approx, norms) = am.decision_batch(&ds.x, MathBackend::Blocked)?;
    let budget = am.znorm_sq_budget();
    let n_in = norms.iter().filter(|&&n| n < budget).count();
    let abs: Vec<f64> = exact
        .iter()
        .zip(&approx)
        .map(|(&e, &a)| f64::from((e - a).abs()))
        .collect();
    Ok(ErrorReport {
        exact_acc: accuracy(&labels_from_decisions(&exact), &ds.y),
        approx_acc: accuracy(&labels_from_decisions(&approx), &ds.y),
        label_diff: label_diff_fraction(&exact, &approx),
        abs_err: Summary::from(&abs),
        in_bound_fraction: n_in as f64 / ds.len().max(1) as f64,
    })
}

/// Histogram of the per-term exponents `2γ x_iᵀ z` over a sample of
/// (SV, instance) pairs — the quantity Eq. (3.9) bounds. Used by the
/// diagnostics CLI to show how conservative Cauchy–Schwarz is (§4.2's
/// d-dependence discussion).
pub fn exponent_histogram(
    model: &SvmModel,
    ds: &Dataset,
    max_pairs: usize,
    rng: &mut crate::util::Rng,
) -> Vec<f64> {
    let gamma = model.kernel.gamma().unwrap_or(0.0);
    let mut out = Vec::new();
    let n_pairs = max_pairs.min(model.n_sv() * ds.len());
    for _ in 0..n_pairs {
        let i = rng.below(model.n_sv());
        let r = rng.below(ds.len());
        let u = 2.0
            * gamma
            * crate::linalg::vecops::dot(model.sv.row(i), ds.x.row(r));
        out.push(f64::from(u));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::builder::build_approx_model;
    use crate::data::synth;
    use crate::svm::smo::{train_csvc, SmoParams};
    use crate::svm::Kernel;

    fn setup(gamma: f32) -> (SvmModel, ApproxModel, Dataset) {
        let ds = synth::two_gaussians(61, 300, 8, 1.5);
        let scaled = crate::data::UnitNormScaler.apply_dataset(&ds);
        let (model, _) =
            train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
                .unwrap();
        let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
        (model, am, scaled)
    }

    #[test]
    fn in_bound_gamma_gives_tiny_diff() {
        let (model, am, ds) = setup(0.2); // γ < γ_max = 0.25
        let rep = compare(&model, &am, &ds).unwrap();
        assert!(rep.in_bound_fraction > 0.999, "{}", rep.in_bound_fraction);
        assert!(rep.label_diff < 0.01, "diff {}", rep.label_diff);
        assert!((rep.exact_acc - rep.approx_acc).abs() < 0.02);
    }

    #[test]
    fn oversized_gamma_grows_diff() {
        let (m1, a1, d1) = setup(0.2);
        let (m2, a2, d2) = setup(2.0); // 8× over γ_max
        let r1 = compare(&m1, &a1, &d1).unwrap();
        let r2 = compare(&m2, &a2, &d2).unwrap();
        assert!(r2.in_bound_fraction < 0.5);
        assert!(
            r2.abs_err.mean > r1.abs_err.mean,
            "{} vs {}",
            r2.abs_err.mean,
            r1.abs_err.mean
        );
    }

    #[test]
    fn exponent_histogram_within_cauchy_schwarz() {
        let (model, _, ds) = setup(0.2);
        let mut rng = crate::util::Rng::new(3);
        let hist = exponent_histogram(&model, &ds, 500, &mut rng);
        assert_eq!(hist.len(), 500);
        // Cauchy–Schwarz cap: |2γ xᵀz| ≤ 2γ‖x‖‖z‖ ≤ 2·0.2·1·1.
        for &u in &hist {
            assert!(u.abs() <= 0.4 + 1e-4);
        }
    }
}
