//! Data substrate: dense dataset container, LIBSVM-format I/O, feature
//! scaling and the synthetic generators that stand in for the paper's
//! five benchmark datasets (a9a / mnist / ijcnn1 / sensit / epsilon);
//! see DESIGN.md §4–5 for the substitution rationale.

#![forbid(unsafe_code)]

pub mod dataset;
pub mod libsvm_format;
pub mod scale;
pub mod synth;

pub use dataset::Dataset;
pub use scale::{MinMaxScaler, UnitNormScaler};
pub use synth::SynthProfile;
