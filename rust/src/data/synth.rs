//! Synthetic dataset generators standing in for the paper's five LIBSVM
//! benchmark datasets (no network in this environment — DESIGN.md §4).
//!
//! Each profile matches the original on the axes the approximation is
//! sensitive to: dimensionality `d`, feature support/sparsity (⇒ the
//! norm distribution ⇒ `γ_MAX` of Eq. 3.11), class geometry (mixture
//! complexity ⇒ realistic support-vector fractions) and class balance.
//! Sizes are scaled down ~5–10× so SMO training fits the session budget;
//! every phenomenon reproduced in EXPERIMENTS.md is a function of
//! `(d, n_SV, γ‖x‖²)`, not of absolute dataset size.
//!
//! All generators are deterministic in the seed.

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::util::Rng;

/// The five dataset profiles (paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SynthProfile {
    /// a9a-like: binary dummy variables, heavy class overlap, d=123.
    AdultLike,
    /// mnist-like: sparse non-negative [0,1], ~19% density, d=780.
    DigitsLike,
    /// ijcnn1-like: dense low-d well-separated, d=22.
    ControlLike,
    /// sensit-like: dense unit-scaled, noisy 1-vs-rest, d=100.
    VehicleLike,
    /// epsilon-like: dense high-d, d=2000.
    WideLike,
}

pub const ALL_PROFILES: [SynthProfile; 5] = [
    SynthProfile::AdultLike,
    SynthProfile::DigitsLike,
    SynthProfile::ControlLike,
    SynthProfile::VehicleLike,
    SynthProfile::WideLike,
];

impl SynthProfile {
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "adult" | "adult-like" | "a9a" => Ok(SynthProfile::AdultLike),
            "digits" | "digits-like" | "mnist" => Ok(SynthProfile::DigitsLike),
            "control" | "control-like" | "ijcnn1" => {
                Ok(SynthProfile::ControlLike)
            }
            "vehicle" | "vehicle-like" | "sensit" => {
                Ok(SynthProfile::VehicleLike)
            }
            "wide" | "wide-like" | "epsilon" => Ok(SynthProfile::WideLike),
            other => Err(crate::Error::InvalidArg(format!(
                "unknown profile '{other}'"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SynthProfile::AdultLike => "adult-like",
            SynthProfile::DigitsLike => "digits-like",
            SynthProfile::ControlLike => "control-like",
            SynthProfile::VehicleLike => "vehicle-like",
            SynthProfile::WideLike => "wide-like",
        }
    }

    /// Which paper dataset this mirrors.
    pub fn mirrors(&self) -> &'static str {
        match self {
            SynthProfile::AdultLike => "a9a",
            SynthProfile::DigitsLike => "mnist",
            SynthProfile::ControlLike => "ijcnn1",
            SynthProfile::VehicleLike => "sensit",
            SynthProfile::WideLike => "epsilon",
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            SynthProfile::AdultLike => 123,
            SynthProfile::DigitsLike => 780,
            SynthProfile::ControlLike => 22,
            SynthProfile::VehicleLike => 100,
            SynthProfile::WideLike => 2000,
        }
    }

    /// Scaled-down (n_train, n_test) defaults.
    pub fn default_sizes(&self) -> (usize, usize) {
        match self {
            SynthProfile::AdultLike => (6000, 4000),
            SynthProfile::DigitsLike => (3000, 2000),
            SynthProfile::ControlLike => (8000, 10000),
            SynthProfile::VehicleLike => (8000, 5000),
            SynthProfile::WideLike => (1500, 1500),
        }
    }

    /// SVM cost parameter that yields paper-like SV fractions.
    pub fn default_cost(&self) -> f32 {
        match self {
            SynthProfile::AdultLike => 1.0,
            SynthProfile::DigitsLike => 2.0,
            SynthProfile::ControlLike => 2.0,
            SynthProfile::VehicleLike => 1.0,
            SynthProfile::WideLike => 1.0,
        }
    }

    /// Generate (train, test) with default sizes.
    pub fn generate_default(&self, seed: u64) -> (Dataset, Dataset) {
        let (ntr, nte) = self.default_sizes();
        self.generate(seed, ntr, nte)
    }

    /// Generate (train, test) deterministically from `seed`.
    pub fn generate(
        &self,
        seed: u64,
        n_train: usize,
        n_test: usize,
    ) -> (Dataset, Dataset) {
        let mut rng = Rng::new(seed ^ 0xDA7A_5E7);
        let total = n_train + n_test;
        let ds = match self {
            SynthProfile::AdultLike => gen_binary_dummies(&mut rng, total, 123),
            SynthProfile::DigitsLike => {
                gen_sparse_nonneg(&mut rng, total, 780, 0.19)
            }
            SynthProfile::ControlLike => {
                gen_gaussian_mixture(&mut rng, total, 22, 6, 1.7, 0.8)
            }
            SynthProfile::VehicleLike => {
                gen_gaussian_mixture(&mut rng, total, 100, 3, 0.75, 1.25)
            }
            SynthProfile::WideLike => {
                gen_gaussian_mixture(&mut rng, total, 2000, 3, 0.85, 1.3)
            }
        };
        let shuffled = ds.shuffled(&mut rng);
        shuffled.split_at(n_train)
    }
}

/// Dense Gaussian mixture: `k` clusters per class on a scaled simplex,
/// class separation `sep`, within-cluster std `noise`. Features are
/// finally squashed to roughly unit scale (x / sqrt(d) style) so norms
/// are d-independent-ish, like unit-scaled real data.
fn gen_gaussian_mixture(
    rng: &mut Rng,
    n: usize,
    d: usize,
    k: usize,
    sep: f64,
    noise: f64,
) -> Dataset {
    // Cluster centers: random directions of length `sep`, mirrored per
    // class with a per-cluster offset so the boundary is multi-modal.
    let latent = d.min(24);
    let mut centers = Vec::new(); // (class, center)
    for class in [1.0f32, -1.0] {
        for _ in 0..k {
            let mut c = vec![0.0f32; d];
            for j in 0..latent {
                c[j] = (rng.normal() * sep * f64::from(class)) as f32;
            }
            // Scatter the remaining dims weakly so high-d profiles are
            // not trivially separable on a low-d subspace.
            for item in c.iter_mut().take(d).skip(latent) {
                *item = (rng.normal() * 0.2) as f32;
            }
            centers.push((class, c));
        }
    }
    let scale = 1.0 / (d as f64).sqrt();
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for r in 0..n {
        let (class, center) = &centers[rng.below(centers.len())];
        y.push(*class);
        let row = x.row_mut(r);
        for j in 0..d {
            row[j] =
                ((f64::from(center[j]) + rng.normal() * noise) * scale) as f32;
        }
    }
    Dataset::new(x, y).expect("valid synth dataset")
}

/// Binary dummy variables (a9a-like): per class, `k` prototype Bernoulli
/// probability vectors; a sample draws its bits from one prototype.
/// Groups of features are one-hot (like a9a's categorical encodings).
fn gen_binary_dummies(rng: &mut Rng, n: usize, d: usize) -> Dataset {
    const K: usize = 4;
    const GROUP: usize = 8; // one-hot group width
    let groups = d / GROUP;
    // Prototypes: per class, per group a categorical distribution.
    let mut protos: Vec<(f32, Vec<Vec<f64>>)> = Vec::new();
    for class in [1.0f32, -1.0] {
        for _ in 0..K {
            let mut dist = Vec::with_capacity(groups);
            for _ in 0..groups {
                let mut p: Vec<f64> =
                    (0..GROUP).map(|_| rng.uniform().powi(2) + 0.02).collect();
                let s: f64 = p.iter().sum();
                for v in &mut p {
                    *v /= s;
                }
                dist.push(p);
            }
            protos.push((class, dist));
        }
    }
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for r in 0..n {
        let (class, dist) = &protos[rng.below(protos.len())];
        y.push(*class);
        let row = x.row_mut(r);
        for (g, p) in dist.iter().enumerate() {
            // Sample one-hot index from the categorical; 10% noise flip.
            let idx = if rng.chance(0.18) {
                rng.below(GROUP)
            } else {
                let u = rng.uniform();
                let mut acc = 0.0;
                let mut pick = GROUP - 1;
                for (i, &pi) in p.iter().enumerate() {
                    acc += pi;
                    if u < acc {
                        pick = i;
                        break;
                    }
                }
                pick
            };
            row[g * GROUP + idx] = 1.0;
        }
        // Trailing features (d % GROUP) stay mostly zero with noise.
        for j in groups * GROUP..d {
            if rng.chance(0.05) {
                row[j] = 1.0;
            }
        }
    }
    Dataset::new(x, y).expect("valid synth dataset")
}

/// Sparse non-negative [0,1] features (mnist-like): per class prototype
/// supports of the target density; values are prototype + noise, clipped.
fn gen_sparse_nonneg(rng: &mut Rng, n: usize, d: usize, density: f64) -> Dataset {
    const K: usize = 8;
    struct Proto {
        class: f32,
        support: Vec<usize>,
        values: Vec<f32>,
    }
    let nsup = ((d as f64) * density) as usize;
    let mut protos = Vec::new();
    for class in [1.0f32, -1.0] {
        // The negative class ("rest") gets more prototypes: it aggregates
        // 9 digits in the original 1-vs-rest task.
        let kk = if class > 0.0 { K / 2 } else { K };
        for _ in 0..kk {
            let support = rng.sample_indices(d, nsup);
            let values =
                (0..nsup).map(|_| rng.range(0.3, 1.0) as f32).collect();
            protos.push(Proto { class, support, values });
        }
    }
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for r in 0..n {
        let p = &protos[rng.below(protos.len())];
        y.push(p.class);
        let row = x.row_mut(r);
        for (s, &j) in p.support.iter().enumerate() {
            if rng.chance(0.85) {
                let v = f64::from(p.values[s]) + rng.normal() * 0.22;
                row[j] = v.clamp(0.0, 1.0) as f32;
            }
        }
        // Cross-class bleed: like confusable digit pairs (4/9, 3/8), a
        // third of samples mix in half of another class's prototype —
        // this drives realistic SV fractions (mnist: ~2k SVs).
        if rng.chance(0.35) {
            let q = &protos[rng.below(protos.len())];
            if q.class != p.class {
                for (s, &j) in q.support.iter().enumerate() {
                    if rng.chance(0.5) {
                        let v = f64::from(q.values[s]) * 0.55
                            + rng.normal() * 0.1;
                        row[j] =
                            (f64::from(row[j]) + v).clamp(0.0, 1.0) as f32;
                    }
                }
            }
        }
        // Background speckle.
        for _ in 0..d / 50 {
            let j = rng.below(d);
            if row[j] == 0.0 && rng.chance(0.3) {
                row[j] = rng.range(0.0, 0.4) as f32;
            }
        }
    }
    Dataset::new(x, y).expect("valid synth dataset")
}

/// Simple two-Gaussian testing helper (not a paper profile).
pub fn two_gaussians(seed: u64, n: usize, d: usize, sep: f64) -> Dataset {
    let mut rng = Rng::new(seed);
    gen_gaussian_mixture(&mut rng, n, d, 1, sep, 0.6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let (a, _) = SynthProfile::ControlLike.generate(7, 100, 50);
        let (b, _) = SynthProfile::ControlLike.generate(7, 100, 50);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x.max_abs_diff(&b.x), 0.0);
        let (c, _) = SynthProfile::ControlLike.generate(8, 100, 50);
        assert!(a.x.max_abs_diff(&c.x) > 0.0);
    }

    #[test]
    fn dims_and_sizes_match_profile() {
        for p in ALL_PROFILES {
            let (tr, te) = p.generate(1, 200, 100);
            assert_eq!(tr.dim(), p.dim(), "{}", p.name());
            assert_eq!(tr.len(), 200);
            assert_eq!(te.len(), 100);
        }
    }

    #[test]
    fn classes_roughly_balanced() {
        for p in [SynthProfile::ControlLike, SynthProfile::AdultLike] {
            let (tr, _) = p.generate(3, 2000, 10);
            let frac = tr.positive_fraction();
            assert!((0.3..0.7).contains(&frac), "{}: {frac}", p.name());
        }
    }

    #[test]
    fn adult_like_is_binary() {
        let (tr, _) = SynthProfile::AdultLike.generate(2, 300, 10);
        for r in 0..tr.len() {
            for &v in tr.x.row(r) {
                assert!(v == 0.0 || v == 1.0);
            }
        }
    }

    #[test]
    fn digits_like_density_near_target() {
        let (tr, _) = SynthProfile::DigitsLike.generate(2, 300, 10);
        let nz: usize = (0..tr.len())
            .map(|r| tr.x.row(r).iter().filter(|&&v| v != 0.0).count())
            .sum();
        let density = nz as f64 / (tr.len() * tr.dim()) as f64;
        assert!((0.10..0.30).contains(&density), "density={density}");
        for r in 0..tr.len() {
            for &v in tr.x.row(r) {
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn mixture_classes_separable_better_than_chance() {
        // Nearest-centroid on the control profile must beat chance by a
        // wide margin; guards against degenerate geometry.
        let (tr, te) = SynthProfile::ControlLike.generate(5, 1000, 500);
        let d = tr.dim();
        let mut cpos = vec![0.0f64; d];
        let mut cneg = vec![0.0f64; d];
        let (mut npos, mut nneg) = (0.0f64, 0.0f64);
        for r in 0..tr.len() {
            let row = tr.x.row(r);
            if tr.y[r] > 0.0 {
                npos += 1.0;
                for j in 0..d {
                    cpos[j] += f64::from(row[j]);
                }
            } else {
                nneg += 1.0;
                for j in 0..d {
                    cneg[j] += f64::from(row[j]);
                }
            }
        }
        for j in 0..d {
            cpos[j] /= npos;
            cneg[j] /= nneg;
        }
        let mut hits = 0;
        for r in 0..te.len() {
            let row = te.x.row(r);
            let dp: f64 = (0..d)
                .map(|j| (f64::from(row[j]) - cpos[j]).powi(2))
                .sum();
            let dn: f64 = (0..d)
                .map(|j| (f64::from(row[j]) - cneg[j]).powi(2))
                .sum();
            let pred = if dp < dn { 1.0 } else { -1.0 };
            if pred == f64::from(te.y[r]) {
                hits += 1;
            }
        }
        let acc = f64::from(hits) / te.len() as f64;
        assert!(acc > 0.7, "nearest-centroid acc {acc}");
    }

    #[test]
    fn profile_parse_aliases() {
        assert_eq!(
            SynthProfile::parse("mnist").unwrap(),
            SynthProfile::DigitsLike
        );
        assert!(SynthProfile::parse("nope").is_err());
    }
}
