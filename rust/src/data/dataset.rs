//! Dense labeled dataset: an `(n × d)` feature matrix plus ±1 labels.
//! LIBSVM sparse files are densified on load — every algorithm here
//! (SMO with dense kernel rows, the approximation builder, the serving
//! hot path) operates on dense rows, exactly like the paper's C++
//! implementation after parsing.

use crate::linalg::Mat;
use crate::{Error, Result};

/// Labeled dataset with ±1 labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Mat,
    pub y: Vec<f32>,
}

impl Dataset {
    pub fn new(x: Mat, y: Vec<f32>) -> Result<Dataset> {
        if x.rows() != y.len() {
            return Err(Error::Shape(format!(
                "{} rows vs {} labels",
                x.rows(),
                y.len()
            )));
        }
        if let Some(bad) = y.iter().find(|&&v| v != 1.0 && v != -1.0) {
            return Err(Error::InvalidArg(format!(
                "labels must be +1/-1, got {bad}"
            )));
        }
        Ok(Dataset { x, y })
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Maximum squared row norm — the `‖x_M‖²` of Eq. (3.11).
    pub fn max_norm_sq(&self) -> f32 {
        self.x.row_norms_sq().into_iter().fold(0.0, f32::max)
    }

    /// Fraction of positive labels.
    pub fn positive_fraction(&self) -> f64 {
        self.y.iter().filter(|&&v| v > 0.0).count() as f64
            / self.len().max(1) as f64
    }

    /// Split into (head, tail) at `count` rows.
    pub fn split_at(&self, count: usize) -> (Dataset, Dataset) {
        assert!(count <= self.len());
        let head = Dataset {
            x: self.x.rows_slice(0, count),
            y: self.y[..count].to_vec(),
        };
        let tail = Dataset {
            x: self.x.rows_slice(count, self.len() - count),
            y: self.y[count..].to_vec(),
        };
        (head, tail)
    }

    /// Deterministically shuffle rows.
    pub fn shuffled(&self, rng: &mut crate::util::Rng) -> Dataset {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        Dataset {
            x: self.x.gather_rows(&idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
        }
    }

    /// Subset by row indices.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.gather_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            Mat::from_vec(4, 2, vec![0., 0., 1., 0., 0., 3., 1., 1.]).unwrap(),
            vec![1.0, -1.0, 1.0, -1.0],
        )
        .unwrap()
    }

    #[test]
    fn constructor_validates() {
        assert!(Dataset::new(Mat::zeros(3, 2), vec![1.0, -1.0]).is_err());
        assert!(Dataset::new(Mat::zeros(2, 2), vec![1.0, 0.5]).is_err());
    }

    #[test]
    fn max_norm_and_balance() {
        let d = tiny();
        assert_eq!(d.max_norm_sq(), 9.0);
        assert!((d.positive_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn split_and_subset() {
        let d = tiny();
        let (a, b) = d.split_at(1);
        assert_eq!(a.len(), 1);
        assert_eq!(b.len(), 3);
        assert_eq!(b.y, vec![-1.0, 1.0, -1.0]);
        let s = d.subset(&[3, 0]);
        assert_eq!(s.x.row(0), &[1., 1.]);
        assert_eq!(s.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn shuffle_preserves_pairs() {
        let d = tiny();
        let mut rng = crate::util::Rng::new(1);
        let s = d.shuffled(&mut rng);
        // Every (row, label) pair of the original must appear once.
        for i in 0..d.len() {
            let found = (0..s.len()).any(|j| {
                s.x.row(j) == d.x.row(i) && s.y[j] == d.y[i]
            });
            assert!(found);
        }
    }
}
