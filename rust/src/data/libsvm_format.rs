//! LIBSVM sparse text format I/O (the format of every dataset the paper
//! uses): `label idx:val idx:val ...` with 1-based, strictly-increasing
//! indices. Densified on read; sparse-written (zeros elided) so model
//! and dataset sizes are comparable to the paper's Table 3 accounting.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::{Error, Result};

/// Parse LIBSVM-format text. `dim_hint` forces the dimensionality
/// (features past it are rejected); with `None` the max seen index wins.
pub fn parse(text: &str, dim_hint: Option<usize>) -> Result<Dataset> {
    let mut rows: Vec<(f32, Vec<(usize, f32)>)> = Vec::new();
    let mut max_idx = 0usize;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let label: f32 = it
            .next()
            .unwrap()
            .parse()
            .map_err(|_| bad(lineno, "label"))?;
        let label = if label > 0.0 { 1.0 } else { -1.0 };
        let mut feats = Vec::new();
        let mut prev = 0usize;
        for tok in it {
            let (i, v) = tok
                .split_once(':')
                .ok_or_else(|| bad(lineno, "feature (idx:val)"))?;
            let idx: usize = i.parse().map_err(|_| bad(lineno, "index"))?;
            let val: f32 = v.parse().map_err(|_| bad(lineno, "value"))?;
            if idx == 0 || idx <= prev {
                return Err(bad(lineno, "indices must be 1-based increasing"));
            }
            prev = idx;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        rows.push((label, feats));
    }
    let d = match dim_hint {
        Some(d) => {
            if max_idx > d {
                return Err(Error::Parse(format!(
                    "feature index {max_idx} exceeds dim hint {d}"
                )));
            }
            d
        }
        None => max_idx,
    };
    let mut x = Mat::zeros(rows.len(), d);
    let mut y = Vec::with_capacity(rows.len());
    for (r, (label, feats)) in rows.into_iter().enumerate() {
        y.push(label);
        for (c, v) in feats {
            *x.at_mut(r, c) = v;
        }
    }
    Dataset::new(x, y)
}

fn bad(lineno: usize, what: &str) -> Error {
    Error::Parse(format!("line {}: bad {what}", lineno + 1))
}

/// Serialize a dataset as LIBSVM sparse text (zeros elided).
pub fn to_string(ds: &Dataset) -> String {
    let mut out = String::new();
    for r in 0..ds.len() {
        out.push_str(if ds.y[r] > 0.0 { "+1" } else { "-1" });
        for (c, &v) in ds.x.row(r).iter().enumerate() {
            if v != 0.0 {
                out.push_str(&format!(" {}:{}", c + 1, fmt_f32(v)));
            }
        }
        out.push('\n');
    }
    out
}

/// Shortest f32 text that round-trips (paper stores models/data as text;
/// Table 3 sizes depend on this).
pub fn fmt_f32(v: f32) -> String {
    let s = format!("{v}");
    debug_assert_eq!(s.parse::<f32>().ok(), Some(v));
    s
}

pub fn load(path: &Path, dim_hint: Option<usize>) -> Result<Dataset> {
    let file = std::fs::File::open(path)?;
    let mut text = String::new();
    let mut reader = std::io::BufReader::new(file);
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        text.push_str(&line);
    }
    parse(&text, dim_hint)
}

pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(to_string(ds).as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_cases;

    #[test]
    fn parse_basic() {
        let ds =
            parse("+1 1:0.5 3:2\n-1 2:1 # comment\n\n+1 1:-3\n", None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.x.row(0), &[0.5, 0.0, 2.0]);
        assert_eq!(ds.x.row(1), &[0.0, 1.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn labels_coerced_to_sign() {
        let ds = parse("3 1:1\n0 1:1\n", None).unwrap();
        assert_eq!(ds.y, vec![1.0, -1.0]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("+1 0:1\n", None).is_err()); // 0-based
        assert!(parse("+1 2:1 1:1\n", None).is_err()); // not increasing
        assert!(parse("+1 1\n", None).is_err()); // missing colon
        assert!(parse("abc 1:1\n", None).is_err()); // bad label
        assert!(parse("+1 5:1\n", Some(3)).is_err()); // beyond hint
    }

    #[test]
    fn dim_hint_pads() {
        let ds = parse("+1 1:1\n", Some(10)).unwrap();
        assert_eq!(ds.dim(), 10);
    }

    #[test]
    fn roundtrip() {
        let src = "+1 1:0.25 4:-3.5\n-1 2:1000\n+1 1:1 2:2 3:3 4:4\n";
        let ds = parse(src, None).unwrap();
        let back = parse(&to_string(&ds), Some(ds.dim())).unwrap();
        assert_eq!(ds.y, back.y);
        assert_eq!(ds.x.max_abs_diff(&back.x), 0.0);
    }

    #[test]
    fn fmt_f32_roundtrips() {
        for v in [0.1f32, -1e-8, 3.4e38, 1.0, -0.0, 123456.78] {
            assert_eq!(fmt_f32(v).parse::<f32>().unwrap(), v);
        }
    }

    #[test]
    fn property_roundtrip_random_sparse() {
        prop_cases!("libsvm-roundtrip", 8, |rng| {
            let n = 1 + rng.below(20);
            let d = 1 + rng.below(30);
            let mut x = Mat::zeros(n, d);
            for r in 0..n {
                for c in 0..d {
                    if rng.chance(0.3) {
                        *x.at_mut(r, c) = rng.normal() as f32;
                    }
                }
            }
            // Ensure the max column is populated so dims survive.
            *x.at_mut(0, d - 1) = 1.0;
            let y: Vec<f32> = (0..n)
                .map(|_| if rng.chance(0.5) { 1.0 } else { -1.0 })
                .collect();
            let ds = Dataset::new(x, y).unwrap();
            let back = parse(&to_string(&ds), Some(d)).unwrap();
            assert_eq!(back.y, ds.y);
            assert_eq!(ds.x.max_abs_diff(&back.x), 0.0);
        });
    }
}
