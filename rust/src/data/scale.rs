//! Feature scaling. The paper's bound (Eq. 3.11) is driven by data
//! norms, so scaling is part of the method's operating envelope: the
//! paper computes `γ_MAX` *after* normalization (Table 1 caption).
//! `MinMaxScaler` mirrors `svm-scale`; `UnitNormScaler` produces the
//! ‖x‖=1 regime of Cao et al. that the paper generalizes away from.

use crate::data::Dataset;
use crate::linalg::Mat;

/// Per-feature affine scaling to `[lo, hi]` (like `svm-scale`).
#[derive(Clone, Debug)]
pub struct MinMaxScaler {
    pub lo: f32,
    pub hi: f32,
    mins: Vec<f32>,
    maxs: Vec<f32>,
}

impl MinMaxScaler {
    /// Fit feature ranges on a training set.
    pub fn fit(x: &Mat, lo: f32, hi: f32) -> MinMaxScaler {
        let d = x.cols();
        let mut mins = vec![f32::INFINITY; d];
        let mut maxs = vec![f32::NEG_INFINITY; d];
        for r in 0..x.rows() {
            for (c, &v) in x.row(r).iter().enumerate() {
                mins[c] = mins[c].min(v);
                maxs[c] = maxs[c].max(v);
            }
        }
        MinMaxScaler { lo, hi, mins, maxs }
    }

    /// Apply to a matrix (constant features map to `lo`).
    pub fn apply(&self, x: &Mat) -> Mat {
        let mut out = x.clone();
        for r in 0..x.rows() {
            let row = out.row_mut(r);
            for c in 0..row.len() {
                let range = self.maxs[c] - self.mins[c];
                row[c] = if range > 0.0 {
                    self.lo
                        + (self.hi - self.lo) * (row[c] - self.mins[c]) / range
                } else {
                    self.lo
                };
            }
        }
        out
    }

    pub fn apply_dataset(&self, ds: &Dataset) -> Dataset {
        Dataset { x: self.apply(&ds.x), y: ds.y.clone() }
    }
}

/// Row-wise scaling to unit L2 norm (zero rows left untouched).
#[derive(Clone, Copy, Debug, Default)]
pub struct UnitNormScaler;

impl UnitNormScaler {
    pub fn apply(&self, x: &Mat) -> Mat {
        let mut out = x.clone();
        for r in 0..x.rows() {
            let row = out.row_mut(r);
            let n = crate::linalg::vecops::norm_sq(row).sqrt();
            if n > 0.0 {
                crate::linalg::vecops::scale(1.0 / n, row);
            }
        }
        out
    }

    pub fn apply_dataset(&self, ds: &Dataset) -> Dataset {
        Dataset { x: self.apply(&ds.x), y: ds.y.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minmax_maps_to_range() {
        let x = Mat::from_vec(3, 2, vec![0., 10., 5., 20., 10., 30.]).unwrap();
        let s = MinMaxScaler::fit(&x, 0.0, 1.0);
        let y = s.apply(&x);
        assert_eq!(y.row(0), &[0.0, 0.0]);
        assert_eq!(y.row(1), &[0.5, 0.5]);
        assert_eq!(y.row(2), &[1.0, 1.0]);
    }

    #[test]
    fn minmax_constant_feature() {
        let x = Mat::from_vec(2, 1, vec![5.0, 5.0]).unwrap();
        let s = MinMaxScaler::fit(&x, -1.0, 1.0);
        assert_eq!(s.apply(&x).row(0), &[-1.0]);
    }

    #[test]
    fn minmax_test_set_can_exceed_range() {
        // svm-scale semantics: apply training ranges verbatim.
        let train = Mat::from_vec(2, 1, vec![0.0, 10.0]).unwrap();
        let s = MinMaxScaler::fit(&train, 0.0, 1.0);
        let test = Mat::from_vec(1, 1, vec![20.0]).unwrap();
        assert_eq!(s.apply(&test).at(0, 0), 2.0);
    }

    #[test]
    fn unit_norm_rows() {
        let x = Mat::from_vec(2, 2, vec![3., 4., 0., 0.]).unwrap();
        let y = UnitNormScaler.apply(&x);
        assert!((crate::linalg::vecops::norm_sq(y.row(0)) - 1.0).abs() < 1e-6);
        assert_eq!(y.row(1), &[0.0, 0.0]); // zero row untouched
    }
}
