//! # approxrbf
//!
//! Production-grade reproduction of *“Fast Prediction with SVM Models
//! Containing RBF Kernels”* (Claesen, De Smet, Suykens, De Moor; stat.ML
//! 2014): a second-order Maclaurin approximation of RBF-kernel decision
//! functions that replaces the `O(n_SV · d)` sum over support vectors
//! with a fixed `O(d²)` quadratic form
//!
//! ```text
//! f̂(z) = exp(-γ‖z‖²) · (c + vᵀz + zᵀMz) + b
//! ```
//!
//! plus the paper's run-time validity bound (Eq. 3.11) made operational
//! as a *bound-aware hybrid router* in the serving layer.
//!
//! ## Architecture (three layers, Python never on the request path)
//!
//! * **L1/L2** — JAX + Pallas kernels (`python/compile/`) AOT-lowered to
//!   HLO text (`make artifacts`).
//! * **Runtime** — [`runtime::Engine`] loads the artifacts via PJRT
//!   (the `xla` crate) and executes them from the Rust hot loop; pure
//!   Rust fallback executors ([`linalg`], [`svm::predict`]) provide the
//!   paper's LOOPS/“BLAS” axes and run without artifacts.
//! * **L3** — [`coordinator`]: request router, dynamic batcher,
//!   bound-aware approx/exact hybrid routing, metrics.
//!
//! ## Substrates
//!
//! Everything the paper depends on is implemented here from scratch:
//! an SMO trainer ([`svm::smo`], the LIBSVM role), LS-SVM ([`svm::lssvm`]),
//! LIBSVM-format data/model I/O ([`data::libsvm_format`], [`svm::model`]),
//! dense linear algebra with naive/blocked backends ([`linalg`]),
//! synthetic dataset generators matched to the paper's five benchmark
//! sets ([`data::synth`]), an ANN comparator ([`svm::ann_approx`]), and a
//! statistics/benchmark harness ([`util::bench`]).

pub mod approx;
pub mod benchsuite;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod runtime;
pub mod svm;
pub mod util;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("parse error: {0}")]
    Parse(String),
    #[error("shape mismatch: {0}")]
    Shape(String),
    #[error("xla/pjrt error: {0}")]
    Xla(String),
    #[error("invalid argument: {0}")]
    InvalidArg(String),
    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Convenience re-exports for examples and binaries.
pub mod prelude {
    pub use crate::approx::{ApproxModel, BoundReport};
    pub use crate::coordinator::{Coordinator, CoordinatorConfig, RoutePolicy};
    pub use crate::data::{Dataset, SynthProfile};
    pub use crate::linalg::{Mat, MathBackend};
    pub use crate::runtime::Engine;
    pub use crate::svm::{Kernel, SmoParams, SvmModel};
    pub use crate::{Error, Result};
}
