//! # approxrbf
//!
//! Production-grade reproduction of *“Fast Prediction with SVM Models
//! Containing RBF Kernels”* (Claesen, De Smet, Suykens, De Moor; stat.ML
//! 2014): a second-order Maclaurin approximation of RBF-kernel decision
//! functions that replaces the `O(n_SV · d)` sum over support vectors
//! with a fixed `O(d²)` quadratic form
//!
//! ```text
//! f̂(z) = exp(-γ‖z‖²) · (c + vᵀz + zᵀMz) + b
//! ```
//!
//! plus the paper's run-time validity bound (Eq. 3.11) made operational
//! as a *bound-aware hybrid router* in the serving layer.
//!
//! ## Quickstart: one trait to evaluate, one client to serve
//!
//! Every substrate — the exact evaluator, the approximated model, the
//! cfg-gated XLA engine — implements the [`predictor::Predictor`]
//! trait, so offline evaluation is uniform:
//!
//! ```text
//! use approxrbf::predictor::{ApproxPredictor, Predictor};
//! use approxrbf::svm::ExactPredictor;
//!
//! let exact  = ExactPredictor::new(&model, MathBackend::Blocked)?;
//! let approx = ApproxPredictor::new(&am, MathBackend::Blocked)?;
//! for p in [&exact as &dyn Predictor, &approx] {
//!     let out = p.predict_batch(&z)?;          // decisions (+ ‖z‖²)
//! }
//! ```
//!
//! Online serving goes through [`coordinator::CoordinatorBuilder`] and
//! a cloneable [`coordinator::Client`] — the crate's only serving
//! ingress; completions are `Result<PredictResponse, PredictError>`, so
//! a request that cannot be served fails fast instead of timing out:
//!
//! ```text
//! let coord = Coordinator::builder()
//!     .policy(RoutePolicy::Hybrid)
//!     .shards(4)                      // 4 executor lanes (default 1)
//!     .start_registry(store.clone())?;
//! let client = coord.client();
//! let mut session = client.session();
//! session.submit_to("tenant-a", features)?;
//! for completion in session.wait_all(timeout)? {
//!     match completion {
//!         Ok(resp) => println!("f(z) = {}", resp.decision),
//!         Err(e) => eprintln!("failed fast: {e}"),   // typed PredictError
//!     }
//! }
//! ```
//!
//! ## Sharding
//!
//! [`coordinator::CoordinatorBuilder::shards`]`(n)` turns the
//! coordinator into a sharded serving plane: `n` independent executor
//! lanes (own ingress queue, batcher, resident-model LRU, metrics
//! sink), with tenants placed by rendezvous hashing on the model id
//! ([`coordinator::shard::assign`]). A model's batches all land on its
//! one owning shard, so an `n`-shard plane returns decisions
//! *identical* to a single-shard one — sharding changes where a tenant
//! is served, never what it is served. Republishing a bundle hot-swaps
//! it on the owning shard; the `.arbf` decode runs on a per-shard
//! prefetch thread, off the request path. Metrics fan in at snapshot
//! time (per-model rows sum across shards and list the owning shard).
//! The `Client` API is identical at every shard count.
//!
//! Per-tenant behavior (route pin, batch shape, residency, quantization
//! drift tolerance) is a [`coordinator::TenantPolicy`] published inside
//! the tenant's `.arbf` bundle via [`registry::ModelStore::publish_with`].
//!
//! ## Network serving
//!
//! The same plane serves over TCP with zero external dependencies
//! ([`net`]): `approxrbf serve-shard` exposes one coordinator process
//! behind the length-prefixed, CRC-checked `ARBW` wire protocol, and a
//! [`net::Router`] places tenants over shard *processes* with the same
//! rendezvous function the in-process `ShardSet` uses — so remote
//! decisions are bit-identical to local ones. [`net::RemoteClient`] /
//! [`net::RemoteSession`] mirror `Client`/`Session` method-for-method;
//! dead shards fail fast with typed errors instead of hanging. See
//! `docs/WIRE.md`.
//!
//! ## Quantized bundles
//!
//! Publishing with [`registry::PublishOptions::quantize`] set to
//! `PayloadKind::F16` or `PayloadKind::Int8` (CLI: `registry publish
//! --quantize f16|int8`) stores the bundle's model payloads quantized
//! (kind-4/5 records, `docs/FORMATS.md`) and serves them from **native
//! quantized storage** — ~2×/4× smaller resident models, so each
//! shard's LRU holds more tenants:
//!
//! ```text
//! store.publish_with("tenant-b", &exact, &approx, PublishOptions {
//!     quantize: Some(PayloadKind::Int8),
//!     ..Default::default()
//! })?;
//! ```
//!
//! Quantized storage is evaluated by the blocked/SIMD kernels in
//! [`linalg::quantblas`] (runtime dispatch:
//! `APPROXRBF_QUANT_KERNEL=scalar|blocked|simd`, default best
//! available). int8 payloads run exact-integer i8×i16 kernels against
//! a query quantized once per row, so int8 decisions are
//! *bit-identical across dispatch arms*; f16 payloads block-dequantize
//! into FMA loops and agree within the advertised bound. The CI
//! `bench-smoke` job gates the int8 blocked/simd arms against the
//! scalar arm on every run (`BENCH_quant.json` kernel-arm sweep).
//!
//! Bound-accounting caveat: the known per-element dequantization error
//! — including the marginal i16 query-quantization term of the int8
//! kernels — is folded into that tenant's Eq. 3.11 routing budget
//! ([`approx::bounds::QuantErrorBound`], tolerance knob
//! [`coordinator::CoordinatorBuilder::quant_drift_tol`]), so Hybrid
//! routing escorts instances whose quantization drift bound exceeds
//! the tolerance — to an exact model that is itself quantized
//! ([`approx::bounds::ExactQuantErr`] reports its drift). Keep
//! margin-critical tenants at f32.
//!
//! ## Random-feature substrate
//!
//! Orthogonal to payload precision, a tenant can be published on the
//! random Fourier feature substrate ([`approx::RffModel`], served by
//! [`predictor::RffPredictor`]): `PublishOptions { substrate:
//! Some(Substrate::Rff), rff_features: Some(d), .. }` or `registry
//! publish --substrate rff --rff-features D`. The kind-6 `.arbf`
//! record stores only `(seed, D, γ, bias, w)` — the D×d projection and
//! phases regenerate deterministically from the seed at load — so the
//! serving footprint is O(D·d) independent of the support-vector count
//! and of γ. Routing consults the stored Monte-Carlo error estimate:
//! the whole tenant serves approx when the estimate fits under
//! `quant_drift_tol`, and escorts everything to exact otherwise
//! (all-or-nothing, unlike Maclaurin's per-instance Eq. 3.11 budget).
//!
//! ## Architecture (three layers, Python never on the request path)
//!
//! * **L1/L2** — JAX + Pallas kernels (`python/compile/`) AOT-lowered to
//!   HLO text (`make artifacts`).
//! * **Runtime** — with the `pjrt` feature, [`runtime`]'s engine loads
//!   the artifacts via PJRT (the `xla` crate) and executes them from the
//!   Rust hot loop; pure Rust fallback executors ([`linalg`],
//!   [`svm::predict`]) provide the paper's LOOPS/“BLAS” axes and run
//!   without artifacts.
//! * **L3** — [`coordinator`]: typed `Client`/`Session` handles over a
//!   sharded executor pool (rendezvous tenant placement, per-shard
//!   dynamic batching), bound-aware approx/exact hybrid routing (every
//!   substrate behind the [`predictor::Predictor`] trait), fail-fast
//!   `PredictError` completions, per-model × per-shard metrics and
//!   policies.
//! * **Registry** — [`registry`]: a versioned, checksummed binary model
//!   format (`.arbf`, see `docs/FORMATS.md`) and a directory-backed
//!   [`registry::ModelStore`] with atomic publish + generation counters,
//!   so one coordinator can serve many tenants and hot-swap republished
//!   models without dropping in-flight requests.
//!
//! ## Substrates
//!
//! Everything the paper depends on is implemented here from scratch:
//! an SMO trainer ([`svm::smo`], the LIBSVM role), LS-SVM ([`svm::lssvm`]),
//! LIBSVM-format data/model I/O ([`data::libsvm_format`], [`svm::model`]),
//! dense linear algebra with naive/blocked backends ([`linalg`]),
//! synthetic dataset generators matched to the paper's five benchmark
//! sets ([`data::synth`]), an ANN comparator ([`svm::ann_approx`]), and a
//! statistics/benchmark harness ([`util::bench`]).
//!
//! ## Invariants are machine-checked
//!
//! Repo-specific invariants that `clippy` cannot express — every
//! `unsafe` block justified, every `APPROXRBF_*` environment variable
//! documented in README's canonical table (see the "Environment
//! variables" section there), wire/format constants in sync with
//! `docs/WIRE.md`/`docs/FORMATS.md`, alloc-bomb caps ahead of every
//! untrusted allocation, and no panic paths in the hot serving modules
//! — are enforced by the in-tree [`analysis`] pass (`cargo run --bin
//! arblint`, rule catalog in `docs/ANALYSIS.md`). Every module without
//! SIMD intrinsics is `#![forbid(unsafe_code)]`; the one exception
//! ([`linalg::quantblas`]) carries `// SAFETY:` proofs under
//! `deny(unsafe_op_in_unsafe_fn)`.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod approx;
pub mod benchsuite;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod net;
pub mod predictor;
pub mod registry;
pub mod runtime;
pub mod svm;
pub mod util;

/// Crate-wide error type.
#[derive(Debug)]
pub enum Error {
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// Malformed text input (datasets, text model formats, JSON).
    Parse(String),
    /// Dimension disagreement between tensors/models.
    Shape(String),
    /// XLA/PJRT runtime failure.
    Xla(String),
    /// Caller passed an unusable argument.
    InvalidArg(String),
    /// Damaged binary artifact: bad magic, checksum mismatch,
    /// truncation, or invalid (e.g. non-finite) payload values.
    Corrupt(String),
    /// Anything else.
    Other(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Xla(m) => write!(f, "xla/pjrt error: {m}"),
            Error::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            Error::Corrupt(m) => write!(f, "corrupt model artifact: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Convenience re-exports for examples and binaries.
pub mod prelude {
    pub use crate::approx::{ApproxModel, BoundReport};
    pub use crate::coordinator::{
        Client, Completion, Coordinator, CoordinatorBuilder,
        CoordinatorConfig, PredictError, PredictErrorKind, PredictResponse,
        RoutePolicy, Session, TenantPolicy, DEFAULT_MODEL,
    };
    pub use crate::data::{Dataset, SynthProfile};
    pub use crate::linalg::{Mat, MathBackend};
    pub use crate::net::{
        RemoteClient, RemoteSession, Router, RouterConfig, ShardServer,
        ShardServerConfig,
    };
    pub use crate::predictor::{ApproxPredictor, PredictOutput, Predictor};
    pub use crate::registry::{
        FormatVersion, ModelStore, PayloadKind, PublishOptions,
        StoreConfig, StoreEntryInfo,
    };
    #[cfg(feature = "pjrt")]
    pub use crate::runtime::Engine;
    pub use crate::svm::{ExactPredictor, Kernel, SmoParams, SvmModel};
    pub use crate::{Error, Result};
}
