//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! `xoshiro256++` seeded through SplitMix64, plus the distribution
//! helpers the repo needs: uniforms, ranges, Box–Muller normals,
//! Fisher–Yates shuffling and subset sampling. Deterministic seeding is
//! load-bearing: every synthetic dataset, SMO tie-break and benchmark
//! workload is reproducible from a seed recorded in EXPERIMENTS.md.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box–Muller draw.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-thread / per-profile use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// non-cryptographic needs: modulo bias is negligible for n << 2^64).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (caches the second deviate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelming odds
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1234);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let a: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
