//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the checksum
//! protecting `.arbf` binary model payloads (see `docs/FORMATS.md`).
//! Table-driven byte-at-a-time implementation; the table is computed at
//! compile time so the substrate stays dependency-free.

const TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 of `data` (standard init `!0`, final complement) — matches
/// zlib's `crc32()`, Python's `zlib.crc32` and the PNG/gzip checksums.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc = TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(
            crc32(b"The quick brown fox jumps over the lazy dog"),
            0x414F_A339
        );
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let mut data = vec![0u8; 64];
        let base = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                data[byte] ^= 1 << bit;
                assert_ne!(crc32(&data), base, "flip at {byte}:{bit}");
                data[byte] ^= 1 << bit;
            }
        }
    }
}
