//! Minimal JSON substrate (no serde offline): a value model, a
//! recursive-descent parser and a writer. Used for metrics snapshots,
//! benchmark result files and config dumps. Supports the full JSON
//! grammar except `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value. Object keys are ordered (BTreeMap) so emitted files are
/// deterministic and diffable across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact single-line encoding.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed encoding with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Parse(format!(
                "trailing junk at byte {} in JSON",
                p.i
            )));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Parse(format!(
                "expected '{}' at byte {}",
                c as char, self.i
            )))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Parse(format!("bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(Error::Parse(format!("unexpected byte {}", self.i))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self
                .peek()
                .ok_or_else(|| Error::Parse("unterminated string".into()))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| {
                        Error::Parse("unterminated escape".into())
                    })?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Parse(
                                    "truncated \\u escape".into(),
                                ));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| {
                                Error::Parse("bad \\u escape".into())
                            })?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| {
                                    Error::Parse("bad \\u escape".into())
                                })?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code).unwrap_or('\u{FFFD}'),
                            );
                        }
                        _ => {
                            return Err(Error::Parse(format!(
                                "bad escape '\\{}'",
                                e as char
                            )))
                        }
                    }
                }
                _ => {
                    // Re-decode UTF-8: back up and take the full char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| Error::Parse("invalid utf-8".into()))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Parse(format!("bad number '{text}'")))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => {
                    return Err(Error::Parse(format!(
                        "expected ',' or ']' at byte {}",
                        self.i
                    )))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => {
                    return Err(Error::Parse(format!(
                        "expected ',' or '}}' at byte {}",
                        self.i
                    )))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compact() {
        let v = Json::obj(vec![
            ("name", Json::str("table2")),
            ("speedup", Json::num(86.5)),
            ("ok", Json::Bool(true)),
            ("tags", Json::Arr(vec![Json::num(1), Json::num(2)])),
            ("nested", Json::obj(vec![("x", Json::Null)])),
        ]);
        let s = v.to_string_compact();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::Arr(vec![
            Json::num(-1.5e-3),
            Json::str("a \"quoted\" str\nwith newline"),
            Json::Obj(Default::default()),
            Json::Arr(vec![]),
        ]);
        let back = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_standard_documents() {
        let doc = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": false},
                      "e": "A\t"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("e").unwrap().as_str().unwrap(),
            "A\t"
        );
    }

    #[test]
    fn integers_emitted_without_exponent() {
        assert_eq!(Json::num(86).to_string_compact(), "86");
        assert_eq!(Json::num(86.5).to_string_compact(), "86.5");
    }

    #[test]
    fn rejects_junk() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }
}
