//! Foundation utilities built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, statistics, benchmark harness, logging and a
//! lightweight property-testing helper.

#![forbid(unsafe_code)]

pub mod bench;
pub mod cli;
pub mod crc32;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;

pub use bench::{BenchConfig, Bencher, Sample};
pub use cli::Args;
pub use json::Json;
pub use rng::Rng;
pub use stats::Summary;
