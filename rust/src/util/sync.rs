//! Poison-tolerant synchronization wrappers for the serving plane.
//!
//! `std`'s `Mutex`/`RwLock`/`Condvar` return a `PoisonError` when some
//! *other* thread panicked while holding the lock. Everywhere in the
//! serving plane the guarded state is kept structurally valid at every
//! await point (bounded queues, counters, connection slots, policy
//! maps), so the least-bad response to poison is to keep serving with
//! the recovered guard instead of cascading the original panic through
//! every lane, tender and pump thread — one crashed worker must not
//! take the plane down. These wrappers centralize that policy (and the
//! reasoning), which lets `arblint`'s no-panic rule forbid bare
//! `.unwrap()` on lock results in `coordinator/`, `net/` and
//! `predictor.rs` outright.
//!
//! The functions are thin: `lock_unpoisoned(&m)` is
//! `m.lock().unwrap_or_else(PoisonError::into_inner)`.

use std::sync::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
    WaitTimeoutResult,
};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a panicking holder poisoned it.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Read-lock an `RwLock`, recovering from poison.
pub fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Write-lock an `RwLock`, recovering from poison.
pub fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Condvar wait, recovering the reacquired guard from poison.
pub fn wait_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(guard)
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Condvar timed wait, recovering the reacquired guard from poison.
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur)
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recovers_after_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn rwlock_recovers_after_poison() {
        let l = Arc::new(RwLock::new(1u32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        *write_unpoisoned(&l) = 2;
        assert_eq!(*read_unpoisoned(&l), 2);
    }

    #[test]
    fn condvar_wait_timeout_returns_guard() {
        let m = Mutex::new(false);
        let cv = Condvar::new();
        let g = lock_unpoisoned(&m);
        let (g, timeout) =
            wait_timeout_unpoisoned(&cv, g, Duration::from_millis(1));
        assert!(timeout.timed_out());
        assert!(!*g);
    }
}
