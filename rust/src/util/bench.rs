//! Benchmark harness substrate ("mini-criterion": no criterion crate
//! offline). Same statistical discipline as the paper's Table 2 rows:
//! warmup, N timed samples, mean ± σ. Used both by `cargo bench` targets
//! (`harness = false`) and by the `bench` CLI subcommand that regenerates
//! the paper's tables.

use std::time::Instant;

use super::json::Json;
use super::stats::Summary;

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Untimed warmup iterations (JIT caches, page faults, turbo).
    pub warmup: usize,
    /// Timed samples.
    pub samples: usize,
    /// Optional wall-clock budget; sampling stops early when exceeded.
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 3, samples: 30, max_seconds: 20.0 }
    }
}

impl BenchConfig {
    /// Quick configuration used by smoke tests / CI.
    pub fn quick() -> Self {
        BenchConfig { warmup: 1, samples: 5, max_seconds: 5.0 }
    }
}

/// Result of one benchmark: timing summary in seconds.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub summary: Summary,
}

impl Sample {
    pub fn mean(&self) -> f64 {
        self.summary.mean
    }

    pub fn std(&self) -> f64 {
        self.summary.std
    }

    /// `12.345 ms ± 0.678` style human rendering.
    pub fn human(&self) -> String {
        format!(
            "{} ± {}",
            humanize_seconds(self.summary.mean),
            humanize_seconds(self.summary.std)
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("n", Json::num(self.summary.n as f64)),
            ("mean_s", Json::num(self.summary.mean)),
            ("std_s", Json::num(self.summary.std)),
            ("min_s", Json::num(self.summary.min)),
            ("p50_s", Json::num(self.summary.p50)),
            ("max_s", Json::num(self.summary.max)),
        ])
    }
}

/// Render seconds at an appropriate scale.
pub fn humanize_seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner.
pub struct Bencher {
    pub config: BenchConfig,
    pub results: Vec<Sample>,
}

impl Bencher {
    pub fn new(config: BenchConfig) -> Self {
        Bencher { config, results: Vec::new() }
    }

    /// Time `f` (which should perform one complete unit of work) and
    /// record the summary under `name`. Returns the recorded sample.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Sample {
        for _ in 0..self.config.warmup {
            f();
        }
        let started = Instant::now();
        let mut times = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
            if started.elapsed().as_secs_f64() > self.config.max_seconds
                && times.len() >= 3
            {
                break;
            }
        }
        let sample =
            Sample { name: name.to_string(), summary: Summary::from(&times) };
        self.results.push(sample.clone());
        sample
    }

    /// Emit all recorded samples as a JSON document.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.results.iter().map(|s| s.to_json()).collect())
    }

    /// Write results to `path` as pretty JSON.
    pub fn write_json(&self, path: &str) -> crate::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

/// Render a markdown table from rows of cells (first row = header).
pub fn markdown_table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows[0].len();
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let mut out = String::new();
    for (ri, row) in rows.iter().enumerate() {
        out.push('|');
        for (i, cell) in row.iter().enumerate() {
            out.push(' ');
            out.push_str(cell);
            for _ in cell.chars().count()..widths[i] {
                out.push(' ');
            }
            out.push_str(" |");
        }
        out.push('\n');
        if ri == 0 {
            out.push('|');
            for w in &widths {
                out.push_str(&"-".repeat(w + 2));
                out.push('|');
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_samples() {
        let mut b = Bencher::new(BenchConfig { warmup: 1, samples: 5, max_seconds: 10.0 });
        let s = b.run("noop-ish", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.summary.n, 5);
        assert!(s.mean() >= 0.0);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn budget_stops_early() {
        let mut b = Bencher::new(BenchConfig { warmup: 0, samples: 1000, max_seconds: 0.05 });
        let s = b.run("sleepy", || std::thread::sleep(std::time::Duration::from_millis(10)));
        assert!(s.summary.n < 1000);
        assert!(s.summary.n >= 3);
    }

    #[test]
    fn humanize() {
        assert_eq!(humanize_seconds(2.5), "2.500 s");
        assert_eq!(humanize_seconds(0.0025), "2.500 ms");
        assert_eq!(humanize_seconds(2.5e-6), "2.500 µs");
        assert_eq!(humanize_seconds(5e-9), "5.0 ns");
    }

    #[test]
    fn markdown_renders() {
        let t = markdown_table(&[
            vec!["a".into(), "bb".into()],
            vec!["ccc".into(), "d".into()],
        ]);
        assert!(t.contains("| a   | bb |"));
        assert!(t.contains("| ccc | d  |"));
        assert!(t.lines().count() == 3);
    }

    #[test]
    fn json_emission() {
        let mut b = Bencher::new(BenchConfig::quick());
        b.run("x", || {});
        let j = b.to_json().to_string_compact();
        assert!(j.contains("\"name\":\"x\""));
    }
}
