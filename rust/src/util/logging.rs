//! Minimal leveled logger (no env_logger offline). Level comes from the
//! `APPROXRBF_LOG` environment variable (`error|warn|info|debug|trace`),
//! defaulting to `info`. Messages go to stderr so stdout stays clean for
//! table/JSON output consumed by scripts.

use std::sync::atomic::{AtomicU8, Ordering};

pub const ERROR: u8 = 1;
pub const WARN: u8 = 2;
pub const INFO: u8 = 3;
pub const DEBUG: u8 = 4;
pub const TRACE: u8 = 5;

static LEVEL: AtomicU8 = AtomicU8::new(0); // 0 = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("APPROXRBF_LOG").ok().as_deref() {
        Some("error") => ERROR,
        Some("warn") => WARN,
        Some("debug") => DEBUG,
        Some("trace") => TRACE,
        Some("off") => 0xFE,
        _ => INFO,
    };
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current level, lazily initialized from the environment.
pub fn level() -> u8 {
    match LEVEL.load(Ordering::Relaxed) {
        0 => init_from_env(),
        l => l,
    }
}

/// Override the level programmatically (tests, `--verbose`).
pub fn set_level(l: u8) {
    LEVEL.store(l, Ordering::Relaxed);
}

pub fn enabled(l: u8) -> bool {
    l <= level() && level() != 0xFE
}

#[doc(hidden)]
pub fn log(l: u8, tag: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::INFO, "info", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::WARN, "warn", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::DEBUG, "debug", format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        set_level(INFO);
        assert!(enabled(ERROR));
        assert!(enabled(INFO));
        assert!(!enabled(DEBUG));
        set_level(TRACE);
        assert!(enabled(DEBUG));
        set_level(INFO);
    }

    #[test]
    fn macros_compile() {
        set_level(0xFE);
        log_info!("hello {}", 1);
        log_warn!("warn {}", 2);
        log_debug!("debug {}", 3);
        set_level(INFO);
    }
}
