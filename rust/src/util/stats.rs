//! Statistics substrate: streaming moments (Welford), percentiles and a
//! printable summary used by the benchmark harness and the metrics
//! subsystem.

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Raw sum of squared deviations (the `M2` term). Together with
    /// [`Welford::from_parts`] this lets an accumulator cross a process
    /// boundary losslessly (the network metrics pull serializes the
    /// moments, not the samples).
    pub fn m2(&self) -> f64 {
        self.m2
    }

    /// Rebuild an accumulator from transported moments — the inverse of
    /// reading `count`/`mean`/`m2`/`min`/`max`. A rebuilt accumulator
    /// merges and reports identically to the original.
    pub fn from_parts(n: u64, mean: f64, m2: f64, min: f64, max: f64) -> Welford {
        if n == 0 {
            return Welford::new();
        }
        Welford { n, mean, m2, min, max }
    }

    /// Combine another accumulator into this one (Chan et al.'s
    /// parallel update), so per-shard moments can be fanned in to one
    /// aggregate without replaying samples.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        self.m2 += other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += delta * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Immutable summary of a sample set with percentiles.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "empty sample");
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min: w.min(),
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: w.max(),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Classification accuracy between prediction and truth (+1/-1 labels).
pub fn accuracy(pred: &[f32], truth: &[f32]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let hits = pred
        .iter()
        .zip(truth)
        .filter(|(p, t)| (p.is_sign_positive()) == (t.is_sign_positive()))
        .count();
    hits as f64 / pred.len() as f64
}

/// Fraction of label disagreements between two predictors (paper Table 1
/// "diff" column: sign disagreements, not misclassifications).
pub fn label_diff_fraction(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let diff = a
        .iter()
        .zip(b)
        .filter(|(x, y)| (x.is_sign_positive()) != (y.is_sign_positive()))
        .count();
    diff as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn welford_merge_equals_single_pass() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0, -3.0, 0.5];
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        // Split at every point, including the degenerate empty halves.
        for split in 0..=xs.len() {
            let (lo, hi) = xs.split_at(split);
            let mut a = Welford::new();
            let mut b = Welford::new();
            for &x in lo {
                a.push(x);
            }
            for &x in hi {
                b.push(x);
            }
            a.merge(&b);
            assert_eq!(a.count(), whole.count(), "split {split}");
            assert!((a.mean() - whole.mean()).abs() < 1e-12);
            assert!((a.var() - whole.var()).abs() < 1e-12);
            assert_eq!(a.min(), whole.min());
            assert_eq!(a.max(), whole.max());
        }
    }

    #[test]
    fn welford_from_parts_roundtrips() {
        let mut w = Welford::new();
        for x in [1.0, 2.5, -3.0, 8.0] {
            w.push(x);
        }
        let back = Welford::from_parts(
            w.count(),
            w.mean(),
            w.m2(),
            w.min(),
            w.max(),
        );
        assert_eq!(back.count(), w.count());
        assert_eq!(back.mean(), w.mean());
        assert_eq!(back.var(), w.var());
        assert_eq!(back.min(), w.min());
        assert_eq!(back.max(), w.max());
        // The degenerate empty transport is a clean new accumulator.
        let empty = Welford::from_parts(0, 0.0, 0.0, 0.0, 0.0);
        assert_eq!(empty.count(), 0);
        assert_eq!(empty.min(), f64::INFINITY);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile_sorted(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 100.0);
    }

    #[test]
    fn summary_fields() {
        let xs = vec![2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let s = Summary::from(&xs);
        assert_eq!(s.n, 8);
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn accuracy_and_diff() {
        let truth = [1.0f32, -1.0, 1.0, -1.0];
        let pred = [0.3f32, -2.0, -0.1, -0.5];
        assert!((accuracy(&pred, &truth) - 0.75).abs() < 1e-12);
        let other = [0.3f32, 2.0, -0.1, -0.5];
        assert!((label_diff_fraction(&pred, &other) - 0.25).abs() < 1e-12);
    }
}
