//! Tiny CLI argument substrate (no clap offline).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]... [positional]...`
//! Flags and options may be interleaved; `--key=value` is accepted too.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand if it
    /// doesn't start with `-`).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Result<Args> {
        let mut out = Args::default();
        let mut tokens = it.into_iter().peekable();
        if let Some(first) = tokens.peek() {
            if !first.starts_with('-') {
                out.subcommand = tokens.next();
            }
        }
        while let Some(tok) = tokens.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    // `--` ends option parsing.
                    out.positionals.extend(tokens);
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if tokens
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = tokens.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Result<Args> {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                Error::InvalidArg(format!("--{name} expects a number, got '{s}'"))
            }),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                Error::InvalidArg(format!(
                    "--{name} expects an integer, got '{s}'"
                ))
            }),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| {
                Error::InvalidArg(format!(
                    "--{name} expects an integer, got '{s}'"
                ))
            }),
        }
    }

    /// Required string option.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| Error::InvalidArg(format!("missing --{name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: a bare flag directly before a positional is ambiguous in
        // a registry-less parser; flags go last or use `--`.
        let a = parse("train --data foo.txt --gamma 0.05 out.model --quiet");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("data"), Some("foo.txt"));
        assert_eq!(a.get_f64("gamma", 1.0).unwrap(), 0.05);
        assert!(a.has_flag("quiet"));
        assert_eq!(a.positionals, vec!["out.model"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --table=2 --samples=30");
        assert_eq!(a.get("table"), Some("2"));
        assert_eq!(a.get_usize("samples", 0).unwrap(), 30);
    }

    #[test]
    fn flag_before_end_and_defaults() {
        let a = parse("serve --verbose");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_or("policy", "hybrid"), "hybrid");
        assert_eq!(a.get_usize("batch", 256).unwrap(), 256);
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse("run --x 1 -- --not-an-option");
        assert_eq!(a.positionals, vec!["--not-an-option"]);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --gamma abc");
        assert!(a.get_f64("gamma", 0.0).is_err());
    }

    #[test]
    fn require_missing() {
        let a = parse("x");
        assert!(a.require("data").is_err());
    }

    #[test]
    fn no_subcommand_when_leading_dash() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.has_flag("help"));
    }
}
