//! Lightweight property-testing substrate (no proptest crate offline).
//!
//! `prop_cases!(N, |rng| { ... })` runs the body N times with forked
//! deterministic RNG streams; on failure the macro reports the case
//! index and seed so the case can be replayed exactly. No shrinking —
//! generators in this repo are parameterized tightly enough that raw
//! counterexamples are readable.

/// Run `n` randomized cases. The closure receives a fresh deterministic
/// [`crate::util::Rng`] per case. Panics propagate with case context.
/// `APPROXRBF_PROP_CASES` caps `n` when set (the CI Miri leg sets it:
/// each interpreted case costs orders of magnitude more than native,
/// and UB detection doesn't need many cases — it needs coverage of
/// each code path, which the first case or two already gives).
pub fn run_cases<F: FnMut(&mut crate::util::Rng)>(
    name: &str,
    n: usize,
    base_seed: u64,
    mut body: F,
) {
    let n = case_cap().map_or(n, |cap| n.min(cap));
    for case in 0..n {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = crate::util::Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || body(&mut rng),
        ));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case}/{n} (seed={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// `APPROXRBF_PROP_CASES` as a positive case cap, if set and valid.
fn case_cap() -> Option<usize> {
    std::env::var("APPROXRBF_PROP_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&cap| cap >= 1)
}

/// Property-test macro: `prop_cases!("name", 32, |rng| { ... });`
#[macro_export]
macro_rules! prop_cases {
    ($name:expr, $n:expr, $body:expr) => {
        $crate::util::proptest::run_cases($name, $n, 0xA11CE, $body)
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        prop_cases!("counting", 17, |_rng| {
            count += 1;
        });
        assert_eq!(count, 17);
    }

    #[test]
    fn rng_streams_differ_between_cases() {
        let mut seen = Vec::new();
        prop_cases!("distinct", 8, |rng| {
            seen.push(rng.next_u64());
        });
        let mut dedup = seen.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seen.len());
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        prop_cases!("failing", 4, |_rng| {
            panic!("boom");
        });
    }
}
