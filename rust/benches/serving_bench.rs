//! Coordinator throughput benchmark: requests/second through the full
//! L3 path under each routing policy and executor (native vs XLA when
//! artifacts are present), plus a shard-scaling sweep over a
//! multi-tenant registry (1/2/4 executor lanes) whose results are
//! written to `BENCH_serving.json`, plus a quantized-payload leg
//! (f32 vs f16 vs int8 bundles: resident model memory, throughput and
//! decision drift vs the reported bound) and a quantized kernel-arm
//! A/B sweep (scalar vs blocked vs simd on larger synthetic shapes,
//! with int8 bit-identity cross-checked) — both written to
//! `BENCH_quant.json` — plus a substrate leg (the same model published
//! on the exact, Maclaurin and random-feature substrates: resident
//! bytes, throughput and observed rff drift vs the stored estimate)
//! written to `BENCH_rff.json`, and a remote-serving leg (the same
//! registry behind two loopback-TCP shard servers fronted by a
//! `Router`, vs the in-process plane) written to `BENCH_remote.json`.
//! The CI `bench-smoke` job runs this with `APPROXRBF_BENCH_SMOKE` set
//! (shorter deterministic sweeps) and fails if an int8 blocked/simd
//! arm does not beat the scalar arm of the same run.
//!
//! Run: `cargo bench --bench serving_bench`

use std::sync::Arc;
use std::time::{Duration, Instant};

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::ApproxModel;
use approxrbf::coordinator::{
    Coordinator, ExecSpec, Route, RoutePolicy, TenantPolicy,
};
use approxrbf::data::{SynthProfile, UnitNormScaler};
use approxrbf::linalg::quantblas::{self, KernelArm};
use approxrbf::linalg::{Mat, MathBackend};
use approxrbf::predictor::{
    Predictor, QuantApproxPredictor, QuantExactPredictor,
};
use approxrbf::registry::quant::{QuantApproxModel, QuantSvmModel};
use approxrbf::registry::{
    ModelStore, PayloadKind, PublishOptions, Substrate,
};
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};
use approxrbf::util::{Json, Rng};

/// Shard sweep: requests per tenant per producer pass.
const SWEEP_CHUNK: usize = 256;
const SWEEP_TENANTS: usize = 6;

/// Short deterministic sweeps for the CI `bench-smoke` job.
fn smoke() -> bool {
    std::env::var("APPROXRBF_BENCH_SMOKE").is_ok()
}

fn main() {
    let requests: usize = if smoke() { 2_000 } else { 10_000 };
    let (n_train, n_test) =
        if smoke() { (1_200, 800) } else { (3_000, 2_000) };
    let (raw_train, raw_test) =
        SynthProfile::ControlLike.generate(11, n_train, n_test);
    let train = UnitNormScaler.apply_dataset(&raw_train);
    let test = UnitNormScaler.apply_dataset(&raw_test);
    let gamma = gamma_max_for_data(&train) * 0.8;
    let (model, stats) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
    println!(
        "# serving throughput (n_sv={}, d={}, {} requests{})\n",
        stats.n_sv,
        train.dim(),
        requests,
        if smoke() { ", smoke sweep" } else { "" }
    );

    #[allow(unused_mut)]
    let mut execs: Vec<(&str, ExecSpec)> =
        vec![("native", ExecSpec::Native(MathBackend::Blocked))];
    #[cfg(feature = "pjrt")]
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        execs.push((
            "xla",
            ExecSpec::Xla { artifacts_dir: "artifacts".into() },
        ));
    } else {
        eprintln!("(artifacts/ missing: skipping XLA executor rows)");
    }

    for (exec_name, exec) in execs {
        for policy in [
            RoutePolicy::AlwaysExact,
            RoutePolicy::AlwaysApprox,
            RoutePolicy::Hybrid,
        ] {
            let coord = Coordinator::builder()
                .policy(policy)
                .exec(exec.clone())
                .max_wait(Duration::from_micros(200))
                .start(model.clone(), am.clone())
                .unwrap();
            let client = coord.client();
            // Warm (compiles XLA executables on first batch).
            let _ = client
                .predict_all(&test.x.rows_slice(0, 64))
                .unwrap();
            let t0 = Instant::now();
            let mut submitted = 0usize;
            let mut received = 0usize;
            while received < requests {
                if submitted < requests {
                    client
                        .submit(test.x.row(submitted % test.len()).to_vec())
                        .unwrap();
                    submitted += 1;
                    while client.recv(Duration::from_micros(0)).is_some() {
                        received += 1;
                    }
                } else if client.recv(Duration::from_millis(100)).is_some() {
                    received += 1;
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let m = coord.metrics();
            println!(
                "exec={exec_name:<7} policy={:<7} {:>9.0} req/s   \
                 mean batch {:>6.1}",
                policy.name(),
                requests as f64 / wall,
                m.mean_batch_size
            );
            // Per-tenant breakdown (single tenant here; the sweep below
            // and examples/multi_tenant_serving.rs show several).
            for line in m.per_model_table().lines().skip(1) {
                println!("    {line}");
            }
            coord.shutdown().unwrap();
        }
    }

    shard_scaling_sweep(&model, &am, &test);
    quant_payload_sweep(&model, &am, &test);
    rff_substrate_sweep(&model, &am, &test);
    remote_loopback_sweep(&model, &am, &test);
}

/// Multi-tenant shard-scaling sweep: the same registry served by 1, 2
/// and 4 executor lanes, driven by one concurrent producer per tenant
/// (scoped threads, each with its own `Client` clone). Emits
/// `BENCH_serving.json`.
fn shard_scaling_sweep(
    model: &approxrbf::svm::SvmModel,
    am: &approxrbf::approx::ApproxModel,
    test: &approxrbf::data::Dataset,
) {
    let dir = std::env::temp_dir().join(format!(
        "approxrbf_serving_bench_registry_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ModelStore::open(&dir).unwrap());
    let tenant_ids: Vec<String> =
        (0..SWEEP_TENANTS).map(|i| format!("tenant-{i}")).collect();
    for id in &tenant_ids {
        store.publish(id, model, am).unwrap();
    }
    let passes: usize = if smoke() { 2 } else { 8 };
    let chunk = test.x.rows_slice(0, SWEEP_CHUNK);
    let per_tenant = SWEEP_CHUNK * passes;
    let total = per_tenant * SWEEP_TENANTS;
    println!(
        "\n# shard scaling ({SWEEP_TENANTS} tenants × {per_tenant} \
         requests, {SWEEP_TENANTS} concurrent producers)\n"
    );
    let mut rows = Vec::new();
    for shards in [1usize, 2, 4] {
        let coord = Coordinator::builder()
            .policy(RoutePolicy::Hybrid)
            .max_wait(Duration::from_micros(200))
            .shards(shards)
            .warm_start(true)
            .start_registry(store.clone())
            .unwrap();
        let client = coord.client();
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for id in &tenant_ids {
                let producer = client.clone();
                let chunk = &chunk;
                scope.spawn(move || {
                    for _ in 0..passes {
                        let responses =
                            producer.predict_all_for(id, chunk).unwrap();
                        assert_eq!(responses.len(), SWEEP_CHUNK);
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let m = coord.metrics();
        assert_eq!(
            (m.served_approx + m.served_exact) as usize,
            total,
            "sweep lost requests"
        );
        let rps = total as f64 / wall;
        println!(
            "shards={shards}  {rps:>9.0} req/s   mean batch \
             {:>6.1}   wall {wall:.2}s",
            m.mean_batch_size
        );
        rows.push(Json::obj(vec![
            ("shards", Json::num(shards as f64)),
            ("requests", Json::num(total as f64)),
            ("wall_s", Json::num(wall)),
            ("throughput_rps", Json::num(rps)),
            ("mean_batch_size", Json::num(m.mean_batch_size)),
            ("mean_latency_s", Json::num(m.mean_latency_s)),
        ]));
        coord.shutdown().unwrap();
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("serving_shard_scaling")),
        ("tenants", Json::num(SWEEP_TENANTS as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_serving.json", doc.to_string_pretty()).unwrap();
    println!("\n(JSON: BENCH_serving.json)");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Remote-serving leg: the same multi-tenant registry served (a) by an
/// in-process two-lane plane and (b) by two single-lane shard servers
/// behind real loopback TCP, fronted by a `Router` — so
/// `BENCH_remote.json` records what the `ARBW` wire (framing, CRC,
/// per-connection threads, socket hops) costs relative to in-process
/// dispatch on identical work. Server-side mean latency rides along to
/// separate wire overhead from executor time.
fn remote_loopback_sweep(
    model: &approxrbf::svm::SvmModel,
    am: &approxrbf::approx::ApproxModel,
    test: &approxrbf::data::Dataset,
) {
    use approxrbf::net::{
        Router, RouterConfig, ShardServer, ShardServerConfig,
    };
    let dir = std::env::temp_dir().join(format!(
        "approxrbf_serving_bench_remote_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ModelStore::open(&dir).unwrap());
    let tenant_ids: Vec<String> =
        (0..SWEEP_TENANTS).map(|i| format!("tenant-{i}")).collect();
    for id in &tenant_ids {
        store.publish(id, model, am).unwrap();
    }
    let passes: usize = if smoke() { 2 } else { 8 };
    // Smoke must shrink the per-pass chunk too, not just the pass
    // count: every remote request pays wire framing + a socket hop, so
    // a pass-count-only shrink left this the slowest smoke leg by far
    // (and the local legs shrink their request counts, not just their
    // repetitions).
    let chunk_rows = if smoke() { 64 } else { SWEEP_CHUNK };
    let chunk = test.x.rows_slice(0, chunk_rows);
    let per_tenant = chunk_rows * passes;
    let total = per_tenant * SWEEP_TENANTS;
    println!(
        "\n# remote serving (in-process vs loopback wire, \
         {SWEEP_TENANTS} tenants × {per_tenant} requests)\n"
    );
    let mut rows = Vec::new();

    // Leg A: in-process plane, two executor lanes.
    {
        let coord = Coordinator::builder()
            .policy(RoutePolicy::Hybrid)
            .max_wait(Duration::from_micros(200))
            .shards(2)
            .warm_start(true)
            .start_registry(store.clone())
            .unwrap();
        let client = coord.client();
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for id in &tenant_ids {
                let producer = client.clone();
                let chunk = &chunk;
                scope.spawn(move || {
                    for _ in 0..passes {
                        let responses =
                            producer.predict_all_for(id, chunk).unwrap();
                        assert_eq!(responses.len(), chunk_rows);
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let m = coord.metrics();
        assert_eq!((m.served_approx + m.served_exact) as usize, total);
        let rps = total as f64 / wall;
        println!(
            "mode=local            {rps:>9.0} req/s   mean batch \
             {:>6.1}   wall {wall:.2}s",
            m.mean_batch_size
        );
        rows.push(Json::obj(vec![
            ("mode", Json::str("local")),
            ("requests", Json::num(total as f64)),
            ("wall_s", Json::num(wall)),
            ("throughput_rps", Json::num(rps)),
            ("mean_batch_size", Json::num(m.mean_batch_size)),
            ("server_mean_latency_s", Json::num(m.mean_latency_s)),
        ]));
        coord.shutdown().unwrap();
    }

    // Leg B: two single-lane shard servers on loopback TCP behind a
    // Router — same lane count, plus the wire.
    {
        let bind_shard = |shard_id: u32| {
            let coord = Coordinator::builder()
                .policy(RoutePolicy::Hybrid)
                .max_wait(Duration::from_micros(200))
                .shards(1)
                .warm_start(true)
                .start_registry(store.clone())
                .unwrap();
            ShardServer::bind(
                "127.0.0.1:0",
                coord,
                store.clone(),
                ShardServerConfig { shard_id, ..Default::default() },
            )
            .unwrap()
        };
        let s0 = bind_shard(0);
        let s1 = bind_shard(1);
        let addrs =
            vec![s0.local_addr().to_string(), s1.local_addr().to_string()];
        let router = Router::connect(&addrs, RouterConfig::default())
            .expect("loopback shard servers reachable");
        let client = router.client();
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for id in &tenant_ids {
                let producer = client.clone();
                let chunk = &chunk;
                scope.spawn(move || {
                    for _ in 0..passes {
                        let responses =
                            producer.predict_all_for(id, chunk).unwrap();
                        assert_eq!(responses.len(), chunk_rows);
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let m = router.metrics();
        assert_eq!(
            (m.served_approx + m.served_exact) as usize,
            total,
            "remote leg lost requests"
        );
        let rps = total as f64 / wall;
        println!(
            "mode=remote-loopback  {rps:>9.0} req/s   mean batch \
             {:>6.1}   wall {wall:.2}s",
            m.mean_batch_size
        );
        rows.push(Json::obj(vec![
            ("mode", Json::str("remote-loopback")),
            ("requests", Json::num(total as f64)),
            ("wall_s", Json::num(wall)),
            ("throughput_rps", Json::num(rps)),
            ("mean_batch_size", Json::num(m.mean_batch_size)),
            ("server_mean_latency_s", Json::num(m.mean_latency_s)),
        ]));
        router.shutdown();
        s0.shutdown().unwrap();
        s1.shutdown().unwrap();
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("serving_remote_loopback")),
        ("tenants", Json::num(SWEEP_TENANTS as f64)),
        ("shard_processes", Json::num(2.0)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_remote.json", doc.to_string_pretty()).unwrap();
    println!("\n(JSON: BENCH_remote.json)");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Quantized-payload leg: the same model published as f32, f16 and
/// int8 bundles, each served through the full Client path. Measures
/// resident model memory (the footprint multiplier quantization buys),
/// artifact bytes, end-to-end throughput, and the worst observed
/// approx-decision drift vs the f32 bundle against the bound
/// `approx/bounds.rs` reports. Emits `BENCH_quant.json`.
fn quant_payload_sweep(
    model: &approxrbf::svm::SvmModel,
    am: &approxrbf::approx::ApproxModel,
    test: &approxrbf::data::Dataset,
) {
    let quant_requests: usize = if smoke() { 1_024 } else { 4_096 };
    let drift_rows: usize = if smoke() { 128 } else { 512 };
    let dir = std::env::temp_dir().join(format!(
        "approxrbf_serving_bench_quant_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ModelStore::open(&dir).unwrap());
    println!(
        "\n# quantized payloads (n_sv={}, d={}, {quant_requests} requests \
         per payload kind)\n",
        model.n_sv(),
        model.dim()
    );
    let mut rows = Vec::new();
    let mut f32_resident = 0usize;
    // Captured during the F32 iteration (which runs first): the twin
    // every quantized payload's drift is measured against.
    let mut f32_entry = None;
    for kind in [PayloadKind::F32, PayloadKind::F16, PayloadKind::Int8] {
        let id = format!("quant-{kind}");
        store
            .publish_with(
                &id,
                model,
                am,
                PublishOptions {
                    quantize: Some(kind),
                    ..Default::default()
                },
            )
            .unwrap();
        let info = store.peek(&id).unwrap();
        let entry = store.load(&id).unwrap();
        let resident = entry.resident_bytes();
        if kind == PayloadKind::F32 {
            f32_resident = resident;
            f32_entry = Some(entry.clone());
        }
        let twin = f32_entry.as_ref().expect("F32 iteration runs first");
        let ratio = f32_resident as f64 / resident as f64;
        // Per-row: the approx drift vs the f32 twin must stay within
        // the per-row reported bound; record the maxima for the JSON.
        let quant_err = entry.quant_info().map(|q| q.approx_err);
        let mut max_drift = 0f64;
        let mut max_bound = 0f64;
        for r in 0..drift_rows.min(test.len()) {
            let z = test.x.row(r);
            let drift = f64::from(
                (entry.approx_decision_one(z)
                    - twin.approx_decision_one(z))
                .abs(),
            );
            let bound = match &quant_err {
                Some(q) => f64::from(q.decision_error(
                    approxrbf::linalg::vecops::norm_sq(z),
                )),
                None => 0.0,
            };
            assert!(
                drift <= bound.max(1e-9),
                "{kind}: row {r} drift {drift} exceeds its reported \
                 bound {bound}"
            );
            max_drift = max_drift.max(drift);
            max_bound = max_bound.max(bound);
        }
        // Throughput through the full serving path (1 shard so payload
        // kinds compete on identical plumbing).
        let coord = Coordinator::builder()
            .policy(RoutePolicy::Hybrid)
            .max_wait(Duration::from_micros(200))
            .shards(1)
            .start_registry(store.clone())
            .unwrap();
        let client = coord.client();
        let _ = client
            .predict_all_for(&id, &test.x.rows_slice(0, 64))
            .unwrap();
        let t0 = Instant::now();
        let mut submitted = 0usize;
        let mut received = 0usize;
        let mut approx_routed = 0usize;
        while received < quant_requests {
            if submitted < quant_requests {
                client
                    .submit_to(
                        &id,
                        test.x.row(submitted % test.len()).to_vec(),
                    )
                    .unwrap();
                submitted += 1;
                while let Some(c) = client.recv(Duration::from_micros(0)) {
                    let resp = c.unwrap();
                    approx_routed += (resp.route == Route::Approx) as usize;
                    received += 1;
                }
            } else if let Some(c) = client.recv(Duration::from_millis(100)) {
                let resp = c.unwrap();
                approx_routed += (resp.route == Route::Approx) as usize;
                received += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let rps = quant_requests as f64 / wall;
        coord.shutdown().unwrap();
        println!(
            "payload={:<5} resident {resident:>9} B ({ratio:>4.1}x \
             smaller)   file {:>9} B   {rps:>9.0} req/s   approx-routed \
             {approx_routed}/{quant_requests}   max drift {max_drift:.2e} \
             (bound {max_bound:.2e})",
            kind.name(),
            info.size_bytes
        );
        rows.push(Json::obj(vec![
            ("payload", Json::str(kind.name())),
            ("resident_bytes", Json::num(resident as f64)),
            ("resident_ratio_vs_f32", Json::num(ratio)),
            ("file_bytes", Json::num(info.size_bytes as f64)),
            ("throughput_rps", Json::num(rps)),
            ("requests", Json::num(quant_requests as f64)),
            ("approx_routed", Json::num(approx_routed as f64)),
            ("max_abs_drift_vs_f32", Json::num(max_drift)),
            ("reported_drift_bound", Json::num(max_bound)),
        ]));
    }
    let arm_rows = kernel_arm_sweep();
    let doc = Json::obj(vec![
        ("bench", Json::str("serving_quantized_payloads")),
        ("n_sv", Json::num(model.n_sv() as f64)),
        ("dim", Json::num(model.dim() as f64)),
        ("rows", Json::Arr(rows)),
        ("kernel_arms", Json::Arr(arm_rows)),
    ]);
    std::fs::write("BENCH_quant.json", doc.to_string_pretty()).unwrap();
    println!("\n(JSON: BENCH_quant.json)");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Substrate leg: the same trained model published on the exact
/// (policy-pinned), Maclaurin and random-feature substrates, each
/// served through the full Client path on one executor lane. Records
/// resident model memory, artifact bytes, throughput, route mix, and
/// the worst observed rff drift vs the exact reference against the
/// stored Monte-Carlo estimate. Emits `BENCH_rff.json`.
fn rff_substrate_sweep(
    model: &approxrbf::svm::SvmModel,
    am: &approxrbf::approx::ApproxModel,
    test: &approxrbf::data::Dataset,
) {
    let requests: usize = if smoke() { 1_024 } else { 4_096 };
    let drift_rows: usize = if smoke() { 128 } else { 512 };
    let rff_features: usize = 2_048;
    let dir = std::env::temp_dir().join(format!(
        "approxrbf_serving_bench_rff_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ModelStore::open(&dir).unwrap());
    store
        .publish_with(
            "subst-exact",
            model,
            am,
            PublishOptions {
                policy: Some(TenantPolicy {
                    route: Some(RoutePolicy::AlwaysExact),
                    ..Default::default()
                }),
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    store
        .publish_with(
            "subst-maclaurin",
            model,
            am,
            PublishOptions {
                substrate: Some(Substrate::Maclaurin),
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    store
        .publish_with(
            "subst-rff",
            model,
            am,
            PublishOptions {
                substrate: Some(Substrate::Rff),
                rff_features: Some(rff_features),
                ..Default::default()
            },
        )
        .unwrap();
    let rff_entry = store.load("subst-rff").unwrap();
    let err_est = rff_entry.models.rff().expect("rff entry").err_est;
    let exact_entry = store.load("subst-exact").unwrap();
    println!(
        "\n# substrates (n_sv={}, d={}, D={rff_features}, rff err≈\
         {err_est:.2e}, {requests} requests per substrate)\n",
        model.n_sv(),
        model.dim()
    );
    // Worst observed rff drift vs the exact reference — the number the
    // stored estimate is supposed to dominate.
    let mut max_drift = 0f64;
    for r in 0..drift_rows.min(test.len()) {
        let z = test.x.row(r);
        let drift = f64::from(
            (rff_entry.approx_decision_one(z)
                - exact_entry.exact_decision_one(z))
            .abs(),
        );
        max_drift = max_drift.max(drift);
    }
    // One hybrid plane over all three tenants; the tolerance sits just
    // above the stored estimate so the rff all-or-nothing gate opens.
    let coord = Coordinator::builder()
        .policy(RoutePolicy::Hybrid)
        .max_wait(Duration::from_micros(200))
        .shards(1)
        .quant_drift_tol((err_est * 1.25).max(1.0))
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    let mut rows = Vec::new();
    for id in ["subst-exact", "subst-maclaurin", "subst-rff"] {
        let info = store.peek(id).unwrap();
        let resident = store.load(id).unwrap().resident_bytes();
        let _ = client
            .predict_all_for(id, &test.x.rows_slice(0, 64))
            .unwrap();
        let t0 = Instant::now();
        let mut submitted = 0usize;
        let mut received = 0usize;
        let mut approx_routed = 0usize;
        while received < requests {
            if submitted < requests {
                client
                    .submit_to(id, test.x.row(submitted % test.len()).to_vec())
                    .unwrap();
                submitted += 1;
                while let Some(c) = client.recv(Duration::from_micros(0)) {
                    let resp = c.unwrap();
                    approx_routed += (resp.route == Route::Approx) as usize;
                    received += 1;
                }
            } else if let Some(c) = client.recv(Duration::from_millis(100)) {
                let resp = c.unwrap();
                approx_routed += (resp.route == Route::Approx) as usize;
                received += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let rps = requests as f64 / wall;
        println!(
            "substrate={:<9} resident {resident:>9} B   file {:>9} B   \
             {rps:>9.0} req/s   approx-routed {approx_routed}/{requests}",
            id.trim_start_matches("subst-"),
            info.size_bytes
        );
        rows.push(Json::obj(vec![
            ("substrate", Json::str(id.trim_start_matches("subst-"))),
            ("resident_bytes", Json::num(resident as f64)),
            ("file_bytes", Json::num(info.size_bytes as f64)),
            ("throughput_rps", Json::num(rps)),
            ("requests", Json::num(requests as f64)),
            ("approx_routed", Json::num(approx_routed as f64)),
        ]));
    }
    coord.shutdown().unwrap();
    let doc = Json::obj(vec![
        ("bench", Json::str("serving_rff_substrate")),
        ("n_sv", Json::num(model.n_sv() as f64)),
        ("dim", Json::num(model.dim() as f64)),
        ("rff_features", Json::num(rff_features as f64)),
        ("rff_err_est", Json::num(f64::from(err_est))),
        ("rff_max_abs_drift_vs_exact", Json::num(max_drift)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_rff.json", doc.to_string_pretty()).unwrap();
    println!(
        "\n(JSON: BENCH_rff.json; worst rff drift {max_drift:.2e} vs \
         stored estimate {err_est:.2e})"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kernel-arm A/B sweep: the same quantized models evaluated through
/// every available dispatch arm (`scalar` = the PR-4 per-element
/// loops) on serving-sized synthetic shapes, so `BENCH_quant.json`
/// records the speedup the blocked/SIMD kernels buy *relative to the
/// scalar arm of the same run* — hardware-noise-proof, which is what
/// the CI `bench-smoke` gate compares. int8 arms are cross-checked
/// bit-identical while we're at it.
fn kernel_arm_sweep() -> Vec<Json> {
    let d = 256;
    let n_sv = 512;
    let batch_rows = 64;
    let mut rng = Rng::new(42);
    let mut sym = Mat::zeros(d, d);
    for r in 0..d {
        for c in r..d {
            let v = (rng.normal() * 0.05) as f32;
            *sym.at_mut(r, c) = v;
            *sym.at_mut(c, r) = v;
        }
    }
    let am = ApproxModel {
        gamma: 0.05,
        b: 0.1,
        c: 0.3,
        v: (0..d).map(|_| (rng.normal() * 0.2) as f32).collect(),
        m: sym,
        max_sv_norm_sq: 1.0,
    };
    let mut sv = Mat::zeros(n_sv, d);
    for r in 0..n_sv {
        for c in 0..d {
            *sv.at_mut(r, c) = (rng.normal() * 0.1) as f32;
        }
    }
    let coef: Vec<f32> = (0..n_sv).map(|_| rng.normal() as f32).collect();
    let exact =
        SvmModel::new(Kernel::Rbf { gamma: 0.05 }, sv, coef, 0.05).unwrap();
    let batch = Mat::from_vec(
        batch_rows,
        d,
        (0..batch_rows * d)
            .map(|_| (rng.normal() * 0.3) as f32)
            .collect(),
    )
    .unwrap();
    let (reps_a, reps_e) = if smoke() { (30, 10) } else { (120, 40) };
    println!(
        "\n# quantized kernel arms (synthetic d={d}, n_sv={n_sv}, \
         batch {batch_rows}; arm speedups vs the scalar arm)\n"
    );
    let mut out = Vec::new();
    for kind in [PayloadKind::F16, PayloadKind::Int8] {
        let qa = QuantApproxModel::quantize(&am, kind).unwrap();
        let qe = QuantSvmModel::quantize(&exact, kind).unwrap();
        let mut scalar_rps = [0f64; 2]; // [approx, exact]
        let mut int8_oracle: Option<Vec<u32>> = None;
        for arm in quantblas::available_arms() {
            let ap = QuantApproxPredictor::with_arm(&qa, arm);
            let ep = QuantExactPredictor::with_arm(&qe, arm);
            // int8 bit-identity across arms, checked on live outputs.
            if kind == PayloadKind::Int8 {
                let bits: Vec<u32> = ap
                    .predict_batch(&batch)
                    .unwrap()
                    .decisions
                    .iter()
                    .chain(&ep.predict_batch(&batch).unwrap().decisions)
                    .map(|x| x.to_bits())
                    .collect();
                match &int8_oracle {
                    None => int8_oracle = Some(bits),
                    Some(want) => assert_eq!(
                        &bits, want,
                        "int8 decisions diverge on arm {arm}"
                    ),
                }
            }
            for (path_idx, path) in ["approx", "exact"].iter().enumerate() {
                let reps = if path_idx == 0 { reps_a } else { reps_e };
                // Best-of-3 rounds: robust against scheduler noise.
                let mut best = f64::INFINITY;
                for _ in 0..3 {
                    let t0 = Instant::now();
                    for _ in 0..reps {
                        let n = if path_idx == 0 {
                            ap.predict_batch(&batch).unwrap().decisions.len()
                        } else {
                            ep.predict_batch(&batch).unwrap().decisions.len()
                        };
                        assert_eq!(n, batch_rows);
                    }
                    best = best.min(t0.elapsed().as_secs_f64());
                }
                let rps = (reps * batch_rows) as f64 / best;
                if arm == KernelArm::Scalar {
                    scalar_rps[path_idx] = rps;
                }
                let speedup = rps / scalar_rps[path_idx];
                println!(
                    "payload={:<5} path={path:<6} arm={:<8} {rps:>10.0} \
                     rows/s   {speedup:>5.2}x vs scalar",
                    kind.name(),
                    arm.name()
                );
                out.push(Json::obj(vec![
                    ("payload", Json::str(kind.name())),
                    ("path", Json::str(*path)),
                    ("arm", Json::str(arm.name())),
                    ("rows_per_s", Json::num(rps)),
                    ("speedup_vs_scalar", Json::num(speedup)),
                ]));
            }
        }
    }
    out
}
