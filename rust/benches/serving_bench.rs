//! Coordinator throughput benchmark: requests/second through the full
//! L3 path under each routing policy and executor (native vs XLA when
//! artifacts are present).
//!
//! Run: `cargo bench --bench serving_bench`

use std::time::{Duration, Instant};

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::coordinator::{Coordinator, ExecSpec, RoutePolicy};
use approxrbf::data::{SynthProfile, UnitNormScaler};
use approxrbf::linalg::MathBackend;
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::Kernel;

const REQUESTS: usize = 10_000;

fn main() {
    let (raw_train, raw_test) =
        SynthProfile::ControlLike.generate(11, 3000, 2000);
    let train = UnitNormScaler.apply_dataset(&raw_train);
    let test = UnitNormScaler.apply_dataset(&raw_test);
    let gamma = gamma_max_for_data(&train) * 0.8;
    let (model, stats) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
    println!(
        "# serving throughput (n_sv={}, d={}, {} requests)\n",
        stats.n_sv,
        train.dim(),
        REQUESTS
    );

    #[allow(unused_mut)]
    let mut execs: Vec<(&str, ExecSpec)> =
        vec![("native", ExecSpec::Native(MathBackend::Blocked))];
    #[cfg(feature = "pjrt")]
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        execs.push((
            "xla",
            ExecSpec::Xla { artifacts_dir: "artifacts".into() },
        ));
    } else {
        eprintln!("(artifacts/ missing: skipping XLA executor rows)");
    }

    for (exec_name, exec) in execs {
        for policy in [
            RoutePolicy::AlwaysExact,
            RoutePolicy::AlwaysApprox,
            RoutePolicy::Hybrid,
        ] {
            let coord = Coordinator::builder()
                .policy(policy)
                .exec(exec.clone())
                .max_wait(Duration::from_micros(200))
                .start(model.clone(), am.clone())
                .unwrap();
            let client = coord.client();
            // Warm (compiles XLA executables on first batch).
            let _ = client
                .predict_all(&test.x.rows_slice(0, 64))
                .unwrap();
            let t0 = Instant::now();
            let mut submitted = 0usize;
            let mut received = 0usize;
            while received < REQUESTS {
                if submitted < REQUESTS {
                    client
                        .submit(test.x.row(submitted % test.len()).to_vec())
                        .unwrap();
                    submitted += 1;
                    while client.recv(Duration::from_micros(0)).is_some() {
                        received += 1;
                    }
                } else if client.recv(Duration::from_millis(100)).is_some() {
                    received += 1;
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let m = coord.metrics();
            println!(
                "exec={exec_name:<7} policy={:<7} {:>9.0} req/s   \
                 mean batch {:>6.1}",
                policy.name(),
                REQUESTS as f64 / wall,
                m.mean_batch_size
            );
            // Per-tenant breakdown (single tenant here; the registry
            // path in examples/multi_tenant_serving.rs shows several).
            for line in m.per_model_table().lines().skip(1) {
                println!("    {line}");
            }
            coord.shutdown().unwrap();
        }
    }
}
