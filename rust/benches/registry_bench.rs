//! Registry cold-path benchmark: publish→swap latency, bundle decode
//! time and resident footprint of `.arbf` format v1 (heap decode) vs
//! format v2 (zero-copy memory map), written to `BENCH_registry.json`.
//! Two synthetic legs (small and serving-sized large) each publish the
//! same model pair as f32 / f16 / int8 Maclaurin bundles under both
//! formats; the small leg adds a random-feature (kind-6) pair. Every
//! v1/v2 twin is cross-checked bit-identical on live decisions while
//! the numbers are collected, and `heap_bytes + mapped_bytes ==
//! resident_bytes` is asserted on every loaded entry.
//!
//! The CI `bench-smoke` job runs this with `APPROXRBF_BENCH_SMOKE` set
//! (smaller large leg, fewer reps) and gates on the **large int8**
//! rows: v2 must strictly beat v1 on swap latency and on resident heap
//! bytes (the number the LRU budget charges; see
//! `ModelEntry::heap_bytes`). The structural half of that claim —
//! mapped payload present, heap residue below the v1 twin — is also
//! asserted here so a local run fails the same way the gate would.
//!
//! The rff pair rides the small leg only: `RffModel::fit` inside
//! `publish_with` costs `O(n_sv·d·(D + n_sv))` for its Monte-Carlo
//! error estimate, which on the large shapes would dwarf the store
//! path under measurement (the printed output says so; nothing is
//! silently dropped).
//!
//! Run: `cargo bench --bench registry_bench`

use std::sync::Arc;
use std::time::Instant;

use approxrbf::approx::ApproxModel;
use approxrbf::linalg::Mat;
use approxrbf::registry::{
    binfmt, FormatVersion, MapFile, ModelEntry, ModelStore, PayloadKind,
    PublishOptions, Substrate,
};
use approxrbf::svm::{Kernel, SvmModel};
use approxrbf::util::{Json, Rng};

/// Short deterministic sweeps for the CI `bench-smoke` job.
fn smoke() -> bool {
    std::env::var("APPROXRBF_BENCH_SMOKE").is_ok()
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Synthetic serving-sized model pair (same construction as the
/// serving bench's kernel-arm sweep, sized per leg).
fn synth_pair(seed: u64, d: usize, n_sv: usize) -> (SvmModel, ApproxModel) {
    let mut rng = Rng::new(seed);
    let mut sym = Mat::zeros(d, d);
    for r in 0..d {
        for c in r..d {
            let v = (rng.normal() * 0.05) as f32;
            *sym.at_mut(r, c) = v;
            *sym.at_mut(c, r) = v;
        }
    }
    let am = ApproxModel {
        gamma: 0.05,
        b: 0.1,
        c: 0.3,
        v: (0..d).map(|_| (rng.normal() * 0.2) as f32).collect(),
        m: sym,
        max_sv_norm_sq: 1.0,
    };
    let mut sv = Mat::zeros(n_sv, d);
    for r in 0..n_sv {
        for c in 0..d {
            *sv.at_mut(r, c) = (rng.normal() * 0.1) as f32;
        }
    }
    let coef: Vec<f32> = (0..n_sv).map(|_| rng.normal() as f32).collect();
    let exact =
        SvmModel::new(Kernel::Rbf { gamma: 0.05 }, sv, coef, 0.05).unwrap();
    (exact, am)
}

/// One (leg, payload, format) measurement.
struct Case {
    row: Json,
    entry: Arc<ModelEntry>,
    swap_s: f64,
    heap_bytes: usize,
}

fn bench_case(
    store: &ModelStore,
    leg: &str,
    payload: &str,
    exact: &SvmModel,
    am: &ApproxModel,
    base: &PublishOptions,
    format: FormatVersion,
) -> Case {
    let (reps, decode_reps) = if smoke() { (7, 9) } else { (11, 25) };
    let id = format!("{leg}-{payload}-{format}");
    let mut publish_s = Vec::with_capacity(reps);
    let mut swap_s = Vec::with_capacity(reps);
    let mut entry = None;
    for _ in 0..reps {
        let opts =
            PublishOptions { format: Some(format), ..base.clone() };
        let t0 = Instant::now();
        store.publish_with(&id, exact, am, opts).unwrap();
        let t1 = Instant::now();
        // publish_with dropped the cached entry, so this load is the
        // cold hot-swap path the shard prefetcher takes: header peek,
        // map, decode.
        let e = store.load(&id).unwrap();
        swap_s.push(t1.elapsed().as_secs_f64());
        publish_s.push(t1.duration_since(t0).as_secs_f64());
        entry = Some(e);
    }
    let entry = entry.unwrap();
    // Decode-only: the binfmt layer over an already-open map. The v1
    // arm heap-decodes from the mapped bytes, the v2 arm hands out
    // views; both CRC the full payload first.
    let map =
        MapFile::open(&store.root().join(format!("{id}.arbf"))).unwrap();
    let mut decode_s = Vec::with_capacity(decode_reps);
    for _ in 0..decode_reps {
        let t0 = Instant::now();
        let b = binfmt::decode_bundle_mapped(&map).unwrap();
        decode_s.push(t0.elapsed().as_secs_f64());
        assert_eq!(b.format, format);
    }
    let info = store.peek(&id).unwrap();
    assert_eq!(info.format, format);
    let (publish, swap, decode) =
        (median(publish_s), median(swap_s), median(decode_s));
    let (heap, mapped) = (entry.heap_bytes(), entry.mapped_bytes());
    assert_eq!(heap + mapped, entry.resident_bytes());
    println!(
        "leg={leg:<5} payload={payload:<4} fmt={format}  file {:>9} B  \
         heap {:>9} B  mapped {:>9} B  publish {:>8.1} µs  \
         swap {:>8.1} µs  decode {:>8.1} µs",
        info.size_bytes,
        heap,
        mapped,
        publish * 1e6,
        swap * 1e6,
        decode * 1e6,
    );
    Case {
        row: Json::obj(vec![
            ("leg", Json::str(leg)),
            ("payload", Json::str(payload)),
            ("format", Json::str(format.to_string())),
            ("dim", Json::num(exact.dim() as f64)),
            ("n_sv", Json::num(exact.n_sv() as f64)),
            ("file_bytes", Json::num(info.size_bytes as f64)),
            ("publish_s", Json::num(publish)),
            ("swap_s", Json::num(swap)),
            ("decode_s", Json::num(decode)),
            ("heap_bytes", Json::num(heap as f64)),
            ("mapped_bytes", Json::num(mapped as f64)),
            ("resident_bytes", Json::num(entry.resident_bytes() as f64)),
        ]),
        entry,
        swap_s: swap,
        heap_bytes: heap,
    }
}

fn main() {
    let (large_d, large_n_sv) =
        if smoke() { (128, 1024) } else { (256, 4096) };
    println!(
        "# registry formats: v1 heap decode vs v2 zero-copy map \
         (large leg d={large_d}, n_sv={large_n_sv}{})\n",
        if smoke() { ", smoke sweep" } else { "" }
    );
    let dir = std::env::temp_dir().join(format!(
        "approxrbf_registry_bench_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let store = ModelStore::open(&dir).unwrap();
    let mut probe_rng = Rng::new(7);
    let mut rows = Vec::new();
    for (leg, d, n_sv) in
        [("small", 32, 96), ("large", large_d, large_n_sv)]
    {
        let (exact, am) = synth_pair(11 + d as u64, d, n_sv);
        let mut probes = Vec::new();
        for _ in 0..4 {
            let mut z = vec![0f32; d];
            for x in z.iter_mut() {
                *x = (probe_rng.normal() * 0.3) as f32;
            }
            probes.push(z);
        }
        let mut cases: Vec<(&str, PublishOptions)> = vec![
            (
                "f32",
                PublishOptions {
                    quantize: Some(PayloadKind::F32),
                    substrate: Some(Substrate::Maclaurin),
                    ..Default::default()
                },
            ),
            (
                "f16",
                PublishOptions {
                    quantize: Some(PayloadKind::F16),
                    ..Default::default()
                },
            ),
            (
                "int8",
                PublishOptions {
                    quantize: Some(PayloadKind::Int8),
                    ..Default::default()
                },
            ),
        ];
        if leg == "small" {
            cases.push((
                "rff",
                PublishOptions {
                    substrate: Some(Substrate::Rff),
                    rff_features: Some(2048),
                    ..Default::default()
                },
            ));
        } else {
            println!(
                "(large leg skips rff: the publish-time fit would dwarf \
                 the store path under measurement)"
            );
        }
        for (payload, base) in &cases {
            let v1 = bench_case(
                &store, leg, payload, &exact, &am, base, FormatVersion::V1,
            );
            let v2 = bench_case(
                &store, leg, payload, &exact, &am, base, FormatVersion::V2,
            );
            // Served decisions must be bit-identical across formats.
            for z in &probes {
                assert_eq!(
                    v1.entry.approx_decision_one(z).to_bits(),
                    v2.entry.approx_decision_one(z).to_bits(),
                    "{leg}/{payload}: v1/v2 approx decisions diverge"
                );
                assert_eq!(
                    v1.entry.exact_decision_one(z).to_bits(),
                    v2.entry.exact_decision_one(z).to_bits(),
                    "{leg}/{payload}: v1/v2 exact decisions diverge"
                );
            }
            println!(
                "    -> {leg}/{payload}: v2 swap {:.2}x vs v1, resident \
                 heap {:.1}x smaller",
                v1.swap_s / v2.swap_s.max(1e-12),
                v1.heap_bytes as f64 / v2.heap_bytes.max(1) as f64
            );
            // The structural half of the bench-smoke gate, pre-checked
            // so a local run fails the same way CI would (latency is
            // left to the gate: it compares the JSON medians).
            if cfg!(target_endian = "little")
                && leg == "large"
                && *payload == "int8"
            {
                assert!(
                    v2.entry.mapped_bytes() > 0,
                    "large int8 v2 entry is not served from the map"
                );
                assert!(
                    v2.heap_bytes < v1.heap_bytes,
                    "large int8: v2 resident heap {} B is not below \
                     the v1 twin's {} B",
                    v2.heap_bytes,
                    v1.heap_bytes
                );
            }
            rows.push(v1.row);
            rows.push(v2.row);
        }
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("registry_formats")),
        ("smoke", Json::Bool(smoke())),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_registry.json", doc.to_string_pretty()).unwrap();
    println!("\n(JSON: BENCH_registry.json)");
    let _ = std::fs::remove_dir_all(&dir);
}
