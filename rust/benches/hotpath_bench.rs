//! Micro-benchmarks of the two hot kernels (mini-criterion harness,
//! `harness = false`): the batched quadratic form (prediction) and the
//! weighted SYRK (approximation build), across backends and sizes.
//!
//! Run: `cargo bench --bench hotpath_bench`

use approxrbf::linalg::{quadform, syrk, Mat};
use approxrbf::util::bench::{BenchConfig, Bencher};
use approxrbf::util::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let mut bench = Bencher::new(BenchConfig {
        warmup: 3,
        samples: 20,
        max_seconds: 10.0,
    });
    println!("# hot-path micro-benchmarks\n");

    for d in [32usize, 128, 512] {
        let mut m = Mat::zeros(d, d);
        for a in 0..d {
            for b in a..d {
                let v = rng.normal() as f32;
                *m.at_mut(a, b) = v;
                *m.at_mut(b, a) = v;
            }
        }
        let z = Mat::from_vec(
            256,
            d,
            (0..256 * d).map(|_| rng.normal() as f32).collect(),
        )
        .unwrap();
        let s = bench.run(&format!("quadform_scalar d={d} batch=256"), || {
            for r in 0..z.rows() {
                std::hint::black_box(quadform::quadform_scalar(&m, z.row(r)));
            }
        });
        println!("{:<36} {}", s.name, s.human());
        let s = bench.run(&format!("quadform_simd   d={d} batch=256"), || {
            for r in 0..z.rows() {
                std::hint::black_box(quadform::quadform_symmetric(
                    &m,
                    z.row(r),
                ));
            }
        });
        println!("{:<36} {}", s.name, s.human());
        let s = bench.run(&format!("quadform_batch  d={d} batch=256"), || {
            std::hint::black_box(quadform::quadform_batch(&m, &z));
        });
        println!("{:<36} {}", s.name, s.human());
    }

    println!();
    for (n, d) in [(2048usize, 64usize), (4096, 128), (2048, 512)] {
        let x = Mat::from_vec(
            n,
            d,
            (0..n * d).map(|_| rng.normal() as f32).collect(),
        )
        .unwrap();
        let w: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let s = bench.run(&format!("syrk_loops   n={n} d={d}"), || {
            std::hint::black_box(syrk::syrk_weighted_loops(&x, &w));
        });
        println!("{:<36} {}", s.name, s.human());
        let s = bench.run(&format!("syrk_blocked n={n} d={d}"), || {
            std::hint::black_box(syrk::syrk_weighted_blocked(&x, &w));
        });
        println!("{:<36} {}", s.name, s.human());
    }

    bench.write_json("results/hotpath_bench.json").ok();
    println!("\n(JSON: results/hotpath_bench.json)");
}
