//! `cargo bench` entry that regenerates the paper's evaluation at quick
//! scale (the full-scale run is `approxrbf bench all --scale full`,
//! recorded in EXPERIMENTS.md). One bench target per paper artifact so
//! `cargo bench` exercises every table and figure end-to-end.
//!
//! Run: `cargo bench --bench paper_tables_bench`

use approxrbf::benchsuite::{self, BenchContext, Scale};

fn main() {
    let ctx = BenchContext::new(Scale::Quick, 42);
    let artifacts = std::path::Path::new("artifacts");
    println!("(quick scale; full tables: `approxrbf bench all --scale full`)\n");
    match benchsuite::fig1::run() {
        Ok(s) => println!("{s}"),
        Err(e) => eprintln!("fig1 failed: {e}"),
    }
    match benchsuite::table1::run(&ctx) {
        Ok(s) => println!("{s}"),
        Err(e) => eprintln!("table1 failed: {e}"),
    }
    match benchsuite::table2::run(&ctx, Some(artifacts)) {
        Ok(s) => println!("{s}"),
        Err(e) => eprintln!("table2 failed: {e}"),
    }
    match benchsuite::table3::run(&ctx) {
        Ok(s) => println!("{s}"),
        Err(e) => eprintln!("table3 failed: {e}"),
    }
    match benchsuite::ablations::run(&ctx) {
        Ok(s) => println!("{s}"),
        Err(e) => eprintln!("ablations failed: {e}"),
    }
    match benchsuite::ann::run(&ctx) {
        Ok(s) => println!("{s}"),
        Err(e) => eprintln!("ann comparison failed: {e}"),
    }
}
