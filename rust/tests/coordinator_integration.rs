//! Coordinator integration: serving through the full L3 stack with both
//! native and (when artifacts exist) XLA executors, plus crate-level
//! property tests on routing invariants. Everything goes through the
//! `Client` API — the only ingress since the sharded-plane release.

use std::time::Duration;

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::coordinator::{Coordinator, Route};
use approxrbf::data::{Dataset, SynthProfile, UnitNormScaler};
use approxrbf::linalg::MathBackend;
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};
use approxrbf::util::Rng;

fn setup(
    gamma_mult: f32,
) -> (SvmModel, approxrbf::approx::ApproxModel, Dataset) {
    let (raw_train, raw_test) = SynthProfile::ControlLike.generate(5, 500, 400);
    let train = UnitNormScaler.apply_dataset(&raw_train);
    let test = UnitNormScaler.apply_dataset(&raw_test);
    let gamma = gamma_max_for_data(&train) * gamma_mult;
    let (model, _) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
    (model, am, test)
}

#[test]
fn hybrid_serving_accuracy_equals_best_of_both() {
    let (model, am, test) = setup(0.8);
    let coord = Coordinator::builder()
        .start(model.clone(), am.clone())
        .unwrap();
    let responses = coord.client().predict_all(&test.x).unwrap();
    // All in-bound (unit-norm data, γ < γ_max) ⇒ all approx-routed and
    // every decision equals the approx model's direct evaluation.
    for (r, resp) in responses.iter().enumerate() {
        assert!(resp.in_bound);
        assert_eq!(resp.route, Route::Approx);
        let (want, _) = am.decision_one(test.x.row(r));
        assert!((resp.decision - want).abs() < 1e-4);
    }
    let snap = coord.metrics();
    assert_eq!(snap.served_approx as usize, test.len());
    assert!(snap.throughput_rps > 0.0);
    coord.shutdown().unwrap();
}

#[cfg(feature = "pjrt")]
#[test]
fn xla_executor_serves_identically_to_native() {
    use approxrbf::coordinator::ExecSpec;
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let (model, am, test) = setup(0.8);
    let native = Coordinator::builder()
        .start(model.clone(), am.clone())
        .unwrap();
    let xla = Coordinator::builder()
        .exec(ExecSpec::Xla { artifacts_dir: "artifacts".into() })
        .start(model, am)
        .unwrap();
    let sub = test.x.rows_slice(0, 64);
    let rn = native.client().predict_all(&sub).unwrap();
    let rx = xla.client().predict_all(&sub).unwrap();
    for (a, b) in rn.iter().zip(&rx) {
        assert_eq!(a.route, b.route);
        assert!(
            (a.decision - b.decision).abs() < 2e-3 * (1.0 + a.decision.abs()),
            "native {} vs xla {}",
            a.decision,
            b.decision
        );
    }
    native.shutdown().unwrap();
    xla.shutdown().unwrap();
}

#[test]
fn property_hybrid_never_serves_out_of_bound_via_approx() {
    // Crate-level routing invariant, randomized over traffic patterns:
    // under Hybrid, every response served by the approx route must
    // satisfy the Eq. (3.11) bound.
    let (model, am, test) = setup(0.9);
    let coord = Coordinator::builder().start(model, am).unwrap();
    let client = coord.client();
    let mut rng = Rng::new(0xBEEF);
    for _case in 0..4 {
        let mut traffic = test.x.rows_slice(0, 100);
        // Random subset pushed out of bound by large scaling.
        for r in 0..traffic.rows() {
            if rng.chance(0.3) {
                for v in traffic.row_mut(r) {
                    *v *= rng.range(2.5, 6.0) as f32;
                }
            }
        }
        let responses = client.predict_all(&traffic).unwrap();
        for resp in &responses {
            if resp.route == Route::Approx {
                assert!(
                    resp.in_bound,
                    "approx-routed response out of bound (id {})",
                    resp.id
                );
            } else {
                assert!(!resp.in_bound);
            }
        }
    }
    coord.shutdown().unwrap();
}

#[test]
fn property_all_submitted_ids_answered_exactly_once() {
    let (model, am, test) = setup(0.8);
    let coord = Coordinator::builder()
        .max_batch(17) // odd size to stress chunk boundaries
        .max_wait(Duration::from_millis(1))
        .start(model, am)
        .unwrap();
    let client = coord.client();
    let n = 333;
    let mut ids = Vec::new();
    for r in 0..n {
        ids.push(client.submit(test.x.row(r % test.len()).to_vec()).unwrap());
    }
    let mut seen = std::collections::HashSet::new();
    for _ in 0..n {
        let resp = client
            .recv(Duration::from_secs(10))
            .expect("completion")
            .expect("all requests in bound and servable");
        assert!(seen.insert(resp.id), "duplicate id {}", resp.id);
    }
    for id in ids {
        assert!(seen.contains(&id), "lost id {id}");
    }
    coord.shutdown().unwrap();
}

#[test]
fn throughput_scales_with_batching() {
    // Larger max_batch must not reduce throughput on bulk traffic
    // (sanity check on the batching design, not a strict perf bound).
    let (model, am, test) = setup(0.8);
    let mut rates = Vec::new();
    for max_batch in [1usize, 128] {
        let coord = Coordinator::builder()
            .max_batch(max_batch)
            .max_wait(Duration::from_micros(500))
            .start(model.clone(), am.clone())
            .unwrap();
        let t0 = std::time::Instant::now();
        let _ = coord.client().predict_all(&test.x).unwrap();
        rates.push(test.len() as f64 / t0.elapsed().as_secs_f64());
        coord.shutdown().unwrap();
    }
    assert!(
        rates[1] > rates[0] * 0.5,
        "batched serving collapsed: {rates:?}"
    );
}
