//! End-to-end pipeline integration: generate → train → approximate →
//! serialize → reload → predict, asserting the paper's accuracy claims
//! hold across module boundaries (no PJRT required).

use std::path::Path;

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::error_analysis;
use approxrbf::approx::ApproxModel;
use approxrbf::data::{libsvm_format, SynthProfile, UnitNormScaler};
use approxrbf::linalg::MathBackend;
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};

#[test]
fn full_pipeline_roundtrip_through_files() {
    let dir = std::env::temp_dir().join("approxrbf_pipeline_test");
    std::fs::create_dir_all(&dir).unwrap();
    // 1. Generate + persist data in LIBSVM format.
    let (train, test) = SynthProfile::ControlLike.generate(99, 600, 300);
    let train_path = dir.join("train.txt");
    libsvm_format::save(&train, &train_path).unwrap();
    let train2 = libsvm_format::load(&train_path, Some(train.dim())).unwrap();
    assert_eq!(train2.len(), train.len());

    // 2. Train within the validity bound.
    let gamma = gamma_max_for_data(&train2) * 0.8;
    let (model, stats) = train_csvc(
        &train2,
        Kernel::Rbf { gamma },
        SmoParams::default(),
    )
    .unwrap();
    assert!(stats.converged);

    // 3. Model file roundtrip (LIBSVM text format).
    let model_path = dir.join("m.model");
    model.save(&model_path).unwrap();
    let model2 = SvmModel::load(&model_path).unwrap();
    assert_eq!(model2.n_sv(), model.n_sv());

    // 4. Approximate + approx-model file roundtrip.
    let am = build_approx_model(&model2, MathBackend::Blocked).unwrap();
    let approx_path = dir.join("m.approx");
    am.save(&approx_path).unwrap();
    let am2 = ApproxModel::load(&approx_path).unwrap();
    assert!((am2.c - am.c).abs() < 1e-6);

    // 5. Compare predictions end-to-end: reloaded approx vs reloaded
    //    exact on the test set.
    let rep = error_analysis::compare(&model2, &am2, &test).unwrap();
    assert!(
        rep.label_diff < 0.02,
        "label diff {} too high for in-bound gamma",
        rep.label_diff
    );
    assert!(rep.exact_acc > 0.8, "exact acc {}", rep.exact_acc);
}

#[test]
fn table1_phenomenon_diff_grows_with_gamma() {
    // The paper's Table 1 trend: diff% increases as γ/γ_MAX grows.
    let (raw, test) = SynthProfile::ControlLike.generate(7, 800, 400);
    let train = UnitNormScaler.apply_dataset(&raw);
    let test = UnitNormScaler.apply_dataset(&test);
    let gmax = gamma_max_for_data(&train);
    let mut diffs = Vec::new();
    for mult in [0.5f32, 2.0, 8.0] {
        let (model, _) = train_csvc(
            &train,
            Kernel::Rbf { gamma: gmax * mult },
            SmoParams::default(),
        )
        .unwrap();
        let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
        let rep = error_analysis::compare(&model, &am, &test).unwrap();
        diffs.push(rep.label_diff);
    }
    assert!(
        diffs[0] <= diffs[2],
        "diff should grow with gamma ratio: {diffs:?}"
    );
    assert!(diffs[0] < 0.02, "in-bound diff too large: {diffs:?}");
}

#[test]
fn table3_phenomenon_compression_scales_with_nsv_over_d() {
    // Table 3's trend: compression ratio ~ n_SV/d; low-d many-SV models
    // compress hugely, wide models with few SVs can even grow.
    let (low_d, _) = SynthProfile::ControlLike.generate(11, 900, 10);
    let gamma = gamma_max_for_data(&low_d) * 0.8;
    let (m_low, _) = train_csvc(
        &low_d,
        Kernel::Rbf { gamma },
        SmoParams::default(),
    )
    .unwrap();
    let am_low = build_approx_model(&m_low, MathBackend::Blocked).unwrap();
    let ratio_low =
        m_low.text_size_bytes() as f64 / am_low.text_size_bytes() as f64;
    assert!(
        ratio_low > 3.0,
        "low-d/many-SV should compress well: {ratio_low}"
    );
}

#[test]
fn artifacts_manifest_parses_when_present() {
    // Keeps the aot.py contract honest without requiring PJRT.
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let manifest = approxrbf::runtime::Manifest::load(dir).unwrap();
    assert!(manifest.entries.len() >= 10);
    // Every referenced file exists and is non-trivial HLO text.
    for e in &manifest.entries {
        let p = manifest.path_of(e);
        let meta = std::fs::metadata(&p)
            .unwrap_or_else(|_| panic!("missing artifact {}", p.display()));
        assert!(meta.len() > 200, "{} suspiciously small", p.display());
    }
    // All five profiles' dims are covered by approx buckets.
    for d in [22usize, 100, 123, 780, 2000] {
        assert!(
            manifest
                .select(
                    approxrbf::runtime::ArtifactKind::Approx,
                    approxrbf::runtime::ImplKind::Jnp,
                    d,
                    0
                )
                .is_some(),
            "no approx bucket for d={d}"
        );
    }
}
