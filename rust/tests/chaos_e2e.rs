//! Chaos tier: the serving plane under deterministic network faults.
//!
//! Every test routes real ARBW traffic through a
//! [`FaultProxy`](approxrbf::net::FaultProxy) whose fault schedule is
//! a pure function of one u64 seed, and pins an invariant the plane
//! must keep while the weather is bad:
//!
//! * **delays** never change a single decision bit, and the metrics
//!   plane still accounts every request exactly once;
//! * **corruption** is caught by the frame CRC and turned into typed
//!   errors — never a silently wrong answer, never a hang;
//! * **cuts** on one shard's link leave the other shard's tenants
//!   bit-identical to a fault-free plane;
//! * **black holes** are bounded: every accepted request still
//!   completes within the deadline;
//! * **flap partitions** drive the router's reconnect ladder through
//!   its documented 50ms→2s envelope, heal, and resume bit-identical
//!   serving with `Metrics::aggregate` conserving counts across the
//!   reconnects;
//! * a **supervisor** restarts a SIGKILLed shard process on its
//!   pinned address and the plane resumes, with restarts and
//!   reconnects surfaced in the metrics snapshot.
//!
//! Gated by `APPROXRBF_TEST_CHAOS=1` (binds loopback sockets; the
//! supervisor test spawns processes); each test is a silent pass
//! without it. `APPROXRBF_CHAOS_SEED` overrides every test's default
//! seed — each test prints the seed it ran with, so a CI failure
//! names its reproducing command (see `docs/TESTING.md`). Waits
//! derive from `APPROXRBF_TEST_DEADLINE_MS` (`tests/common/mod.rs`).
//! CI runs the suite across a fixed seed matrix in the `tier1-chaos`
//! job (`make test-chaos`).

mod common;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use approxrbf::coordinator::{Coordinator, PredictErrorKind};
use approxrbf::data::Dataset;
use approxrbf::net::{
    FaultPlan, FaultProxy, Router, RouterConfig, ShardServer,
    ShardServerConfig, Supervisor, SupervisorConfig,
};
use approxrbf::registry::ModelStore;

use common::{run_in_process, temp_dir, trained_pair, Served, DRIFT_TOL};

fn chaos_enabled() -> bool {
    match std::env::var("APPROXRBF_TEST_CHAOS") {
        Ok(v) => v == "1",
        Err(_) => false,
    }
}

/// This run's seed: `APPROXRBF_CHAOS_SEED` if set, else the test's
/// own default. Printed unconditionally so any failure in the test
/// body names the exact reproducing command.
fn chaos_seed(default: u64) -> u64 {
    let seed = std::env::var("APPROXRBF_CHAOS_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(default);
    eprintln!(
        "chaos seed {seed} — replay with: APPROXRBF_TEST_CHAOS=1 \
         APPROXRBF_CHAOS_SEED={seed} cargo test --test chaos_e2e -- \
         --test-threads=1"
    );
    seed
}

/// Candidate tenant names; [`chaos_registry`] picks one per shard
/// with the plane's own placement function, so the tests never depend
/// on how any specific name happens to hash.
const CANDIDATES: [&str; 8] = [
    "tenant-a", "tenant-b", "tenant-c", "tenant-d", "tenant-e",
    "tenant-f", "tenant-g", "tenant-h",
];

/// A two-tenant registry where `tenants[i]` is owned by shard `i` of
/// a two-shard plane.
fn chaos_registry(
    tag: &str,
) -> (Arc<ModelStore>, Vec<(&'static str, Dataset)>) {
    let mut ids: [Option<&'static str>; 2] = [None, None];
    for id in CANDIDATES {
        let shard = Router::place_for(id, 2);
        if ids[shard].is_none() {
            ids[shard] = Some(id);
        }
    }
    let store = Arc::new(ModelStore::open(temp_dir(tag)).unwrap());
    let mut tenants = Vec::new();
    for (shard, id) in ids.iter().enumerate() {
        let id = id.unwrap_or_else(|| {
            panic!("candidate pool never hashes to shard {shard}")
        });
        let (m, a, ds) = trained_pair(1000 + 111 * shard as u64, 0.8);
        store.publish(id, &m, &a).unwrap();
        tenants.push((id, ds));
    }
    (store, tenants)
}

/// Deterministic round-robin traffic over the tenant set.
fn build_traffic(
    tenants: &[(&'static str, Dataset)],
    n: usize,
) -> Vec<(&'static str, Vec<f32>)> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let (id, ds) = &tenants[i % tenants.len()];
        let row = (i / tenants.len()) % ds.len();
        out.push((*id, ds.x.row(row).to_vec()));
    }
    out
}

/// An in-process two-shard plane with a fault proxy in front of each
/// shard: Router → FaultProxy i → ShardServer i → Coordinator.
struct ChaosPlane {
    servers: Vec<ShardServer>,
    proxies: Vec<FaultProxy>,
    router: Router,
}

impl ChaosPlane {
    fn spawn(store: &Arc<ModelStore>, plans: [FaultPlan; 2]) -> ChaosPlane {
        let mut servers = Vec::new();
        let mut proxies = Vec::new();
        let mut addrs = Vec::new();
        for (i, plan) in plans.into_iter().enumerate() {
            let coord = Coordinator::builder()
                .shards(1)
                .max_wait(Duration::from_millis(1))
                .quant_drift_tol(DRIFT_TOL.parse().unwrap())
                .start_registry(store.clone())
                .unwrap();
            let server = ShardServer::bind(
                "127.0.0.1:0",
                coord,
                store.clone(),
                ShardServerConfig {
                    shard_id: i as u32,
                    ..Default::default()
                },
            )
            .unwrap();
            let proxy =
                FaultProxy::spawn(server.local_addr(), plan).unwrap();
            addrs.push(proxy.addr().to_string());
            servers.push(server);
            proxies.push(proxy);
        }
        let router = Router::connect(&addrs, RouterConfig::default())
            .expect("router must come up through the proxies");
        ChaosPlane { servers, proxies, router }
    }

    fn teardown(self) {
        let ChaosPlane { servers, proxies, router } = self;
        router.shutdown();
        for p in &proxies {
            p.shutdown();
        }
        for s in servers {
            let _ = s.shutdown();
        }
    }
}

/// Serve `traffic` through the plane expecting zero failures; returns
/// the decisions in submission order.
fn serve_clean(
    router: &Router,
    traffic: &[(&'static str, Vec<f32>)],
) -> Vec<Served> {
    let client = router.client();
    let mut session = client.session();
    for (id, z) in traffic {
        session.submit_to(id, z.clone()).unwrap();
    }
    session
        .wait_all(common::long_deadline())
        .unwrap()
        .into_iter()
        .map(|c| {
            let r = c.expect("plane must serve this request");
            (r.model.to_string(), r.generation, r.decision.to_bits(), r.route)
        })
        .collect()
}

#[test]
fn delays_never_change_bits_and_counts_are_conserved() {
    if !chaos_enabled() {
        eprintln!("skipping: APPROXRBF_TEST_CHAOS != 1");
        return;
    }
    let seed = chaos_seed(0xC4A0_0001);
    let (store, tenants) = chaos_registry("delays");
    let traffic = build_traffic(&tenants, 160);
    let baseline = run_in_process(&store, &traffic);

    let plane = ChaosPlane::spawn(
        &store,
        [FaultPlan::delays(seed), FaultPlan::delays(seed ^ 1)],
    );
    let served = serve_clean(&plane.router, &traffic);
    assert_eq!(
        served, baseline,
        "a delayed plane must stay bit-identical (seed {seed})"
    );

    // Exactly-once accounting survives the slow network.
    let snap = plane.router.metrics();
    assert_eq!(
        snap.served_approx + snap.served_exact,
        traffic.len() as u64,
        "seed {seed}"
    );
    assert_eq!(snap.dropped, 0, "seed {seed}");
    let injected: u64 =
        plane.proxies.iter().map(|p| p.stats().delays).sum();
    assert!(injected > 0, "no delay ever fired (seed {seed})");
    plane.teardown();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn corruption_is_caught_and_every_request_completes() {
    if !chaos_enabled() {
        eprintln!("skipping: APPROXRBF_TEST_CHAOS != 1");
        return;
    }
    let seed = chaos_seed(0xC4A0_0002);
    let (store, tenants) = chaos_registry("corrupt");
    let traffic = build_traffic(&tenants, 200);

    let plane = ChaosPlane::spawn(
        &store,
        [FaultPlan::corruption(seed), FaultPlan::corruption(seed ^ 1)],
    );
    let client = plane.router.client();
    let mut session = client.session();
    let mut accepted = 0u64;
    for (id, z) in &traffic {
        // Submits racing a torn-down link fail fast and typed; they
        // are not owed a completion.
        if session.submit_to(id, z.clone()).is_ok() {
            accepted += 1;
        }
    }
    let completions = session.wait_all(common::long_deadline()).unwrap();
    assert_eq!(
        completions.len() as u64,
        accepted,
        "exactly one completion per accepted request (seed {seed})"
    );
    let mut ok = 0u64;
    for c in &completions {
        match c {
            Ok(_) => ok += 1,
            // A flipped bit must surface as a typed transport error,
            // never as a wrong answer or a hang.
            Err(e) => assert!(
                matches!(
                    e.kind,
                    PredictErrorKind::Exec { .. }
                        | PredictErrorKind::Shutdown
                ),
                "unexpected error kind under corruption: {e} \
                 (seed {seed})"
            ),
        }
    }
    let corrupted: u64 =
        plane.proxies.iter().map(|p| p.stats().corrupted).sum();
    assert!(corrupted >= 1, "no corruption ever fired (seed {seed})");

    // Conservation across the teardown/reconnect cycles: everything
    // the client saw succeed was served, nothing was served twice.
    let conserved = common::poll_until(common::deadline(), || {
        let snap = plane.router.metrics();
        let served = snap.served_approx + snap.served_exact;
        ok <= served && served <= accepted
    });
    assert!(conserved, "metrics lost or duplicated requests (seed {seed})");
    plane.teardown();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn cuts_on_one_shard_leave_the_other_bit_identical() {
    if !chaos_enabled() {
        eprintln!("skipping: APPROXRBF_TEST_CHAOS != 1");
        return;
    }
    let seed = chaos_seed(0xC4A0_0003);
    let (store, tenants) = chaos_registry("cuts");
    let (victim, victim_ds) = (tenants[0].0, &tenants[0].1);
    let survivor_traffic = build_traffic(&tenants[1..2], 120);
    let baseline = run_in_process(&store, &survivor_traffic);

    // Shard 0's link is cut mid-frame on every connection; shard 1's
    // proxy is transparent.
    let plane = ChaosPlane::spawn(
        &store,
        [FaultPlan::cuts(seed), FaultPlan::clean(seed ^ 1)],
    );
    let vclient = plane.router.client();
    let mut v_accepted = 0u64;
    for i in 0..120 {
        let z = victim_ds.x.row(i % victim_ds.len()).to_vec();
        if vclient.submit_to(victim, z).is_ok() {
            v_accepted += 1;
        }
    }

    // The survivor's tenants serve clean and bit-identical while the
    // victim link is being severed over and over.
    let served = serve_clean(&plane.router, &survivor_traffic);
    assert_eq!(
        served, baseline,
        "survivor shard must stay bit-identical (seed {seed})"
    );

    // Exactly-once for the victim too: every accepted request gets
    // one completion (served or typed failure), none hang.
    for i in 0..v_accepted {
        assert!(
            vclient.recv(common::recv_deadline()).is_some(),
            "victim completion {i}/{v_accepted} never arrived \
             (seed {seed})"
        );
    }
    assert!(
        plane.proxies[0].stats().cuts >= 1,
        "no cut ever fired (seed {seed})"
    );
    assert_eq!(plane.proxies[1].stats().cuts, 0);
    plane.teardown();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn black_hole_stalls_are_bounded_and_requests_complete() {
    if !chaos_enabled() {
        eprintln!("skipping: APPROXRBF_TEST_CHAOS != 1");
        return;
    }
    let seed = chaos_seed(0xC4A0_0004);
    let (store, tenants) = chaos_registry("blackhole");
    let traffic = build_traffic(&tenants, 160);

    let plane = ChaosPlane::spawn(
        &store,
        [FaultPlan::black_hole(seed), FaultPlan::black_hole(seed ^ 1)],
    );
    let client = plane.router.client();
    let mut session = client.session();
    let mut accepted = 0u64;
    let t0 = Instant::now();
    for (id, z) in &traffic {
        if session.submit_to(id, z.clone()).is_ok() {
            accepted += 1;
        }
    }
    // The whole point of a *bounded* black hole: the plane never
    // wedges. Every accepted request completes within the deadline —
    // served, or failed typed when the stalled link was severed.
    let completions = session.wait_all(common::long_deadline()).unwrap();
    assert_eq!(
        completions.len() as u64,
        accepted,
        "request lost to the black hole (seed {seed})"
    );
    assert!(
        t0.elapsed() < common::long_deadline(),
        "stall outlived the deadline: {:?} (seed {seed})",
        t0.elapsed()
    );
    let stalls: u64 =
        plane.proxies.iter().map(|p| p.stats().stalls).sum();
    assert!(stalls >= 1, "no stall ever fired (seed {seed})");
    plane.teardown();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn flap_partition_backoff_stays_in_envelope_and_heals() {
    if !chaos_enabled() {
        eprintln!("skipping: APPROXRBF_TEST_CHAOS != 1");
        return;
    }
    let seed = chaos_seed(0xC4A0_0005);
    const REFUSALS: u32 = 4;
    let (store, tenants) = chaos_registry("flap");
    let (victim, victim_ds) = (tenants[0].0, &tenants[0].1);
    let traffic = build_traffic(&tenants, 120);
    let baseline = run_in_process(&store, &traffic);

    let plane = ChaosPlane::spawn(
        &store,
        [FaultPlan::flap(seed, REFUSALS), FaultPlan::clean(seed ^ 1)],
    );
    let mut accepted = 0u64;
    let mut ok_seen = 0u64;

    // Phase 1: push victim traffic until the scheduled cut starts the
    // partition.
    let client = plane.router.client();
    let t0 = Instant::now();
    while plane.proxies[0].stats().cuts == 0 {
        assert!(
            t0.elapsed() < common::deadline(),
            "flap cut never fired (seed {seed})"
        );
        let z = victim_ds.x.row(0).to_vec();
        if client.submit_to(victim, z).is_ok() {
            accepted += 1;
            if let Some(Ok(_)) = client.recv(Duration::from_millis(200))
            {
                ok_seen += 1;
            }
        }
    }

    // Phase 2: the proxy refuses the next REFUSALS reconnection
    // attempts, driving the backoff ladder; then it heals. Healed
    // means a fresh session's victim request round-trips Ok.
    let healed = common::poll_until(common::deadline(), || {
        let c = plane.router.client();
        let mut s = c.session();
        if s.submit_to(victim, victim_ds.x.row(1).to_vec()).is_err() {
            return false;
        }
        accepted += 1;
        match s.wait_all(common::recv_deadline()) {
            Ok(cs) => {
                let all_ok = cs.iter().all(|c| c.is_ok());
                ok_seen += cs.iter().filter(|c| c.is_ok()).count() as u64;
                all_ok
            }
            Err(_) => false,
        }
    });
    assert!(healed, "flap partition never healed (seed {seed})");

    // The refusals really happened, the tender recorded the ladder,
    // and the slept backoff stayed inside the documented envelope.
    let stats = plane.proxies[0].stats();
    assert_eq!(
        stats.refused,
        u64::from(REFUSALS),
        "seed {seed}"
    );
    let health = plane.router.link_health();
    assert!(
        health[0].failures >= u64::from(REFUSALS),
        "refused dials must be recorded as failures: {health:?} \
         (seed {seed})"
    );
    assert!(
        health[0].reconnects >= 1,
        "tender never reconnected: {health:?} (seed {seed})"
    );
    assert!(
        (50..=2000).contains(&health[0].max_backoff_ms),
        "backoff left the 50ms→2s envelope: {health:?} (seed {seed})"
    );

    // Phase 3: the healed plane serves the full workload
    // bit-identically to a fault-free one.
    let served = serve_clean(&plane.router, &traffic);
    assert_eq!(
        served, baseline,
        "healed plane must resume bit-identical (seed {seed})"
    );
    accepted += traffic.len() as u64;
    ok_seen += traffic.len() as u64;

    // Conservation across the whole flap: aggregate never loses or
    // double-counts a request, and the reconnects surface in the
    // snapshot's shard-health rows.
    let conserved = common::poll_until(common::deadline(), || {
        let snap = plane.router.metrics();
        let served_total = snap.served_approx + snap.served_exact;
        ok_seen <= served_total && served_total <= accepted
    });
    assert!(conserved, "metrics lost requests across the flap (seed {seed})");
    let snap = plane.router.metrics();
    let row = snap
        .shard_health
        .iter()
        .find(|h| h.shard == 0)
        .expect("shard 0 health row");
    assert!(row.reconnects >= 1, "seed {seed}");
    plane.teardown();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn supervisor_restarts_crashed_shard_and_plane_resumes() {
    if !chaos_enabled() {
        eprintln!("skipping: APPROXRBF_TEST_CHAOS != 1");
        return;
    }
    let (store, tenants) = chaos_registry("supervisor");
    let (victim, victim_ds) = (tenants[0].0, &tenants[0].1);
    let traffic = build_traffic(&tenants, 80);
    let baseline = run_in_process(&store, &traffic);

    let sup = Supervisor::start(SupervisorConfig {
        shards: 2,
        store: store.root().to_path_buf(),
        binary: PathBuf::from(env!("CARGO_BIN_EXE_approxrbf")),
        drift_tol: Some(DRIFT_TOL.parse().unwrap()),
        health_interval: Duration::from_millis(100),
        ..SupervisorConfig::default()
    })
    .expect("supervisor brings the plane up");
    let router = Router::connect(&sup.addrs(), RouterConfig::default())
        .expect("router connects to the supervised plane");

    // Healthy plane first: bit-identical to in-process.
    assert_eq!(serve_clean(&router, &traffic), baseline);

    // Crash shard 0's process (SIGKILL, no goodbye frame). The
    // supervisor must respawn it on its pinned address and the router
    // must reconnect — full service restored within the deadline.
    sup.kill_shard(0).expect("kill shard 0");
    let restored = common::poll_until(common::deadline(), || {
        let c = router.client();
        let mut s = c.session();
        if s.submit_to(victim, victim_ds.x.row(0).to_vec()).is_err() {
            return false;
        }
        matches!(
            s.wait_all(common::recv_deadline()),
            Ok(cs) if cs.iter().all(|c| c.is_ok())
        )
    });
    assert!(restored, "supervisor never restored shard 0");
    assert!(
        sup.restarts()[0] >= 1,
        "restart not recorded: {:?}",
        sup.restarts()
    );
    assert_eq!(
        sup.addrs().len(),
        2,
        "pinned address list must survive the restart"
    );

    // The restarted plane still serves the exact same bits.
    assert_eq!(
        serve_clean(&router, &traffic),
        baseline,
        "restarted shard must serve bit-identically"
    );

    // Reconnects (router tender) and restarts (supervisor) meet in
    // one metrics snapshot.
    let mut snap = router.metrics();
    snap.record_restarts(&sup.restarts());
    let row = snap
        .shard_health
        .iter()
        .find(|h| h.shard == 0)
        .expect("shard 0 health row");
    assert!(row.restarts >= 1, "snapshot missing supervisor restarts");
    assert!(row.reconnects >= 1, "snapshot missing router reconnects");
    router.shutdown();
    sup.shutdown();
    let _ = std::fs::remove_dir_all(store.root());
}
