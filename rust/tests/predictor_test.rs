//! The unified prediction surface, end to end:
//!
//! * one generic `predict_all<P: Predictor>` harness drives the exact
//!   evaluator, the approximated model and the (stub) XLA-engine-shaped
//!   backend through identical assertions;
//! * every executor-side failure mode is *delivered* as a typed
//!   `Err(PredictError)` completion — unknown model, dimension drift
//!   across an out-of-band republish, post-shutdown submit — well under
//!   any request timeout, instead of silently timing out.

use std::sync::Arc;
use std::time::{Duration, Instant};

use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::ApproxModel;
use approxrbf::coordinator::{Coordinator, PredictErrorKind};
use approxrbf::data::{synth, Dataset, UnitNormScaler};
use approxrbf::linalg::{Mat, MathBackend};
use approxrbf::predictor::{ApproxPredictor, PredictOutput, Predictor};
use approxrbf::registry::ModelStore;
use approxrbf::svm::predict::ExactPredictor;
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};

fn trained_pair(
    seed: u64,
    d: usize,
) -> (SvmModel, ApproxModel, Dataset) {
    let ds = synth::two_gaussians(seed, 200, d, 1.5);
    let scaled = UnitNormScaler.apply_dataset(&ds);
    let gamma = gamma_max_for_data(&scaled) * 0.8;
    let (model, _) =
        train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
    (model, am, scaled)
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("approxrbf_predictor_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ---------------------------------------------------------------------
// the generic harness (acceptance: one fn, every backend)
// ---------------------------------------------------------------------

/// THE harness: everything a caller needs from any backend, written
/// once against the trait.
fn predict_all<P: Predictor + ?Sized>(
    p: &P,
    z: &Mat,
) -> approxrbf::Result<PredictOutput> {
    assert_eq!(p.dim(), z.cols(), "harness caller bug");
    let out = p.predict_batch(z)?;
    assert_eq!(
        out.decisions.len(),
        z.rows(),
        "{}: decision count must equal batch rows",
        p.kind()
    );
    if let Some(norms) = &out.znorms_sq {
        assert_eq!(norms.len(), z.rows(), "{}: norm count", p.kind());
    }
    Ok(out)
}

/// Stand-in for the PJRT engine path when the `pjrt` feature (or the
/// AOT artifacts) are absent: same shape as
/// `runtime::EngineApproxPredictor` — reports decisions *and* norms —
/// but evaluated on the native substrate. Keeps the trait harness
/// exercising three distinct `Predictor` impls in tier-1 builds.
struct StubEnginePredictor<'m> {
    am: &'m ApproxModel,
}

impl Predictor for StubEnginePredictor<'_> {
    fn dim(&self) -> usize {
        self.am.dim()
    }

    fn kind(&self) -> &'static str {
        "approx-xla-stub"
    }

    fn predict_batch(&self, z: &Mat) -> approxrbf::Result<PredictOutput> {
        let (decisions, norms) =
            self.am.decision_batch(z, MathBackend::Blocked)?;
        Ok(PredictOutput { decisions, znorms_sq: Some(norms) })
    }
}

#[test]
fn generic_harness_passes_against_exact_approx_and_stub_pjrt() {
    let (model, am, ds) = trained_pair(41, 7);
    let z = ds.x.rows_slice(0, 50);

    let exact = ExactPredictor::new(&model, MathBackend::Blocked).unwrap();
    let approx = ApproxPredictor::new(&am, MathBackend::Blocked).unwrap();
    let stub = StubEnginePredictor { am: &am };
    let backends: Vec<&dyn Predictor> = vec![&exact, &approx, &stub];

    let mut kinds = Vec::new();
    for p in backends {
        let out = predict_all(p, &z).unwrap();
        kinds.push(p.kind());
        for r in 0..z.rows() {
            // Reference values from the direct (non-trait) evaluators.
            let want = match p.kind() {
                "exact-native" => model.decision_one(z.row(r)),
                _ => am.decision_one(z.row(r)).0,
            };
            assert!(
                (out.decisions[r] - want).abs() < 1e-3,
                "{} row {r}: {} vs {want}",
                p.kind(),
                out.decisions[r]
            );
        }
        // Substrates that report ‖z‖² must agree with a direct
        // computation (the Eq. 3.11 bound check depends on it).
        if let Some(norms) = &out.znorms_sq {
            for r in 0..z.rows() {
                let want: f32 =
                    z.row(r).iter().map(|v| v * v).sum();
                assert!(
                    (norms[r] - want).abs() < 1e-4,
                    "{} row {r}: ‖z‖² {} vs {want}",
                    p.kind(),
                    norms[r]
                );
            }
        }
    }
    assert_eq!(kinds, ["exact-native", "approx-native", "approx-xla-stub"]);

    // Real XLA-engine impl rides the same harness when available.
    #[cfg(feature = "pjrt")]
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        let engine =
            approxrbf::runtime::Engine::load(std::path::Path::new(
                "artifacts",
            ))
            .unwrap();
        let prep = engine.prepare_approx(&am).unwrap();
        let ep =
            approxrbf::runtime::EngineApproxPredictor::new(&engine, &prep);
        let out = predict_all(&ep as &dyn Predictor, &z).unwrap();
        for r in 0..z.rows() {
            let (want, _) = am.decision_one(z.row(r));
            assert!((out.decisions[r] - want).abs() < 2e-3);
        }
    }
}

#[test]
fn mismatched_batch_dim_is_a_shape_error_on_every_backend() {
    let (model, am, _) = trained_pair(42, 6);
    let exact = ExactPredictor::new(&model, MathBackend::Loops).unwrap();
    let approx = ApproxPredictor::new(&am, MathBackend::Loops).unwrap();
    let bad = Mat::zeros(3, 6 + 1);
    for p in [&exact as &dyn Predictor, &approx] {
        assert!(
            matches!(p.predict_batch(&bad), Err(approxrbf::Error::Shape(_))),
            "{}",
            p.kind()
        );
    }
}

// ---------------------------------------------------------------------
// fail-fast PredictError delivery (acceptance: dropped requests return
// Err(PredictError::…) in under the request timeout)
// ---------------------------------------------------------------------

#[test]
fn unknown_model_after_eviction_fails_fast_not_timeout() {
    let store = Arc::new(ModelStore::open(temp_dir("unknown")).unwrap());
    let (m_a, a_a, ds) = trained_pair(5, 6);
    let (m_b, a_b, _) = trained_pair(6, 6);
    store.publish("alpha", &m_a, &a_a).unwrap();
    store.publish("bravo", &m_b, &a_b).unwrap();
    // max_resident_models(1): serving bravo evicts alpha from the
    // executor, so a later alpha request must re-resolve via the store.
    // shards(1) pins both tenants onto ONE executor — the eviction this
    // test depends on only happens when they share a resident set.
    let coord = Coordinator::builder()
        .shards(1)
        .max_resident_models(1)
        .max_wait(Duration::from_millis(1))
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    // Serve alpha (caches its dim at the submit boundary, makes it
    // resident), then bravo (evicts alpha).
    client
        .predict_all_for("alpha", &ds.x.rows_slice(0, 4))
        .unwrap();
    client
        .predict_all_for("bravo", &ds.x.rows_slice(0, 4))
        .unwrap();
    // Out-of-band deletion: the submit-side dim cache still admits
    // alpha, but the executor can no longer resolve it.
    store.remove("alpha").unwrap();
    let mut session = client.session();
    let id = session
        .submit_to("alpha", ds.x.row(0).to_vec())
        .expect("submit admits the cached tenant");
    let t0 = Instant::now();
    let completions = session.wait_all(Duration::from_secs(30)).unwrap();
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_secs(10),
        "fail-fast took {waited:?} (timeout-like)"
    );
    assert_eq!(completions.len(), 1);
    let err = completions[0].as_ref().expect_err("must fail fast");
    assert_eq!(err.id, id);
    assert_eq!(&*err.model, "alpha");
    assert!(
        matches!(err.kind, PredictErrorKind::UnknownModel { .. }),
        "{err}"
    );
    // The failure is also visible operationally.
    let snap = coord.metrics();
    assert!(snap.dropped >= 1);
    coord.shutdown().unwrap();
}

#[test]
fn dim_drift_across_out_of_band_republish_fails_fast() {
    let store = Arc::new(ModelStore::open(temp_dir("dimdrift")).unwrap());
    let (m6, a6, ds6) = trained_pair(7, 6);
    let (m6b, a6b, _) = trained_pair(8, 6);
    let (m10, a10, _) = trained_pair(9, 10);
    store.publish("x", &m6, &a6).unwrap();
    store.publish("y", &m6b, &a6b).unwrap();
    // shards(1): the eviction of 'x' by 'y' requires one executor.
    let coord = Coordinator::builder()
        .shards(1)
        .max_resident_models(1)
        .max_wait(Duration::from_millis(1))
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    client.predict_all_for("x", &ds6.x.rows_slice(0, 4)).unwrap();
    client.predict_all_for("y", &ds6.x.rows_slice(0, 4)).unwrap(); // evicts x
    // Out-of-band feature-space change: remove + republish with d=10.
    // The submit-side cache still says d=6, so the instance is admitted
    // — and must fail fast at the executor, not hang.
    store.remove("x").unwrap();
    store.publish("x", &m10, &a10).unwrap();
    let mut session = client.session();
    session
        .submit_to("x", ds6.x.row(0).to_vec())
        .expect("stale dim cache admits the request");
    let completions = session.wait_all(Duration::from_secs(30)).unwrap();
    let err = completions[0].as_ref().expect_err("must fail fast");
    assert!(
        matches!(
            err.kind,
            PredictErrorKind::DimMismatch { got: 6, want: 10 }
        ),
        "{err}"
    );
    coord.shutdown().unwrap();
}

#[test]
fn post_shutdown_submit_fails_with_shutdown_kind() {
    let (model, am, ds) = trained_pair(11, 6);
    let coord = Coordinator::builder().start(model, am).unwrap();
    let client = coord.client();
    // Healthy before shutdown…
    client.predict_all(&ds.x.rows_slice(0, 2)).unwrap();
    coord.shutdown().unwrap();
    // …typed failure after.
    let err = client.submit(ds.x.row(0).to_vec()).unwrap_err();
    assert_eq!(err.kind, PredictErrorKind::Shutdown);
    // Sessions opened on a dead coordinator fail the same way.
    let mut session = client.session();
    let err = session.submit(ds.x.row(0).to_vec()).unwrap_err();
    assert_eq!(err.kind, PredictErrorKind::Shutdown);
}
