#!/usr/bin/env python3
"""Regenerates the golden `.arbf` corpus (v1 + v2, kinds 1-6).

The committed binaries are CANONICAL: rust/tests/format_conformance.rs
asserts that the Rust encoder reproduces them byte-for-byte, so any
format change must be made deliberately (edit docs/FORMATS.md, bump the
version or add a kind, regenerate here, and update the conformance
expectations).

Format v2 (the zero-copy layout) shares every record kind and the CRC
discipline with v1 but places each payload on a 64-byte file offset:
the record header's formerly-reserved u16 holds the count of zero pad
bytes inserted after the header (not CRC-covered; readers re-derive and
zero-check it), and the quantized kind-4/5 payloads switch to dense
tensors whose segments are zero-padded to 64-byte boundaries inside the
payload (CRC-covered) so typed views can serve straight from a mapped
file. Kinds 1-3 payload bodies are byte-identical across formats; the
kind-6 28-byte prefix is too, with only the weight vector realigned.

Every model value in the corpus is dyadic (a small multiple of a power
of two), and every int8 row max is 127 * 2^-k, so f32 arithmetic, f16
conversion and int8 quantization are all EXACT - this generator and the
Rust encoder agree bit-for-bit with no rounding ambiguity.

Run from the repo root:  python3 rust/tests/data/gen_fixtures.py
"""

import math
import os
import struct
import zlib

HERE = os.path.dirname(os.path.abspath(__file__))

# -- primitives ------------------------------------------------------------


def u8(x):
    return struct.pack("<B", x)


def u16(x):
    return struct.pack("<H", x)


def u32(x):
    return struct.pack("<I", x)


def u64(x):
    return struct.pack("<Q", x)


def i8(x):
    return struct.pack("<b", x)


def f32(x):
    b = struct.pack("<f", x)
    # The corpus must stay exact: refuse values that round in f32.
    assert struct.unpack("<f", b)[0] == x, f"{x} not f32-exact"
    return b


def f16(x):
    """f32 -> f16 bits, exact values only (asserts)."""
    if x == 0:
        return u16(0x8000 if math.copysign(1.0, x) < 0 else 0)
    s = 0x8000 if x < 0 else 0
    m, e = math.frexp(abs(x))  # abs(x) = m * 2^e, m in [0.5, 1)
    exp = e - 1  # normalized exponent: abs(x) = (2m) * 2^exp
    assert -14 <= exp <= 15, f"{x} outside exact-normal f16 range"
    mant = (2 * m - 1) * 1024
    assert mant == int(mant), f"{x} not f16-exact"
    return u16(s | ((exp + 15) << 10) | int(mant))


def record(kind, payload):
    return u16(kind) + u16(0) + u32(zlib.crc32(payload)) + u64(len(payload)) + payload


def arbf(generation, dim, n_sv, flags, records):
    out = b"ARBF" + u16(1) + u16(len(records)) + u64(generation)
    out += u32(dim) + u32(n_sv) + u64(flags)
    for kind, payload in records:
        out += record(kind, payload)
    return out


PAYLOAD_ALIGN = 64


def record_v2(offset, kind, payload):
    """v2 record written at absolute file offset `offset`: the header's
    pad word counts the zero bytes that place the payload on the next
    PAYLOAD_ALIGN-byte file offset. Pad bytes are NOT CRC-covered."""
    pad = -(offset + 16) % PAYLOAD_ALIGN
    out = u16(kind) + u16(pad) + u32(zlib.crc32(payload)) + u64(len(payload))
    return out + b"\x00" * pad + payload


def arbf_v2(generation, dim, n_sv, flags, records):
    out = b"ARBF" + u16(2) + u16(len(records)) + u64(generation)
    out += u32(dim) + u32(n_sv) + u64(flags)
    for kind, payload in records:
        out += record_v2(len(out), kind, payload)
    return out


def pad64(out):
    """Zero-fill to the next PAYLOAD_ALIGN boundary relative to the
    payload start (v2 places payloads on absolute 64-byte offsets, so
    relative alignment is absolute alignment). CRC-covered."""
    return out + b"\x00" * (-len(out) % PAYLOAD_ALIGN)


FLAG_HAS_POLICY = 1
FLAG_QUANT_F16 = 2
FLAG_QUANT_INT8 = 4
FLAG_RFF = 8

# -- the f32/f16 toy pair (all values f16-exact dyadics) -------------------

SVM = dict(
    tag=1,  # rbf
    gamma=0.25,
    beta=0.0,
    b=0.125,
    coef=[0.5, -1.0, 0.75],
    rows=[[1.0, 0.0, 2.0], [0.0, 2.0, 0.0], [-1.0, 1.0, 0.5]],
)
APPROX = dict(
    d=3,
    gamma=0.125,
    b=-0.25,
    c=0.5,
    max_sv_norm_sq=4.0,
    v=[1.0, -2.0, 0.25],
    m_upper=[[0.5, 0.25, -1.0], [-0.75, 2.0], [0.125]],
)
POLICY = u16(1) + u8(2) + u32(32) + u64(750) + u32(5)  # always-exact, 32, 750us, 5

# -- the int8 toy pair (every row max is 127 * 2^-k -> exact scales) -------

SVM8 = dict(
    tag=1,
    gamma=0.25,
    beta=0.0,
    b=0.125,
    coef=dict(scale=0.0078125, q=[127, -64, 32]),
    rows=[
        dict(scale=0.0078125, q=[127, 0, 64]),
        dict(scale=0.0078125, q=[0, 127, 0]),
        dict(scale=0.00390625, q=[-127, 64, 0]),
    ],
)
APPROX8 = dict(
    d=3,
    gamma=0.125,
    b=-0.25,
    c=0.5,
    max_sv_norm_sq=4.0,
    v=dict(scale=0.0078125, q=[127, -64, 32]),
    m_upper=[
        dict(scale=0.0078125, q=[127, 32, -64]),
        dict(scale=0.0078125, q=[-127, 96]),
        dict(scale=0.00390625, q=[127]),
    ],
)

# -- the rff record (kind 6; W and phases regenerate from the seed) --------

RFF = dict(
    dim=3,
    seed=42,
    gamma=0.125,
    bias=0.125,
    err_est=0.25,
    w=[0.5, -1.0, 0.25, 2.0],
)

# -- payload builders ------------------------------------------------------


def svm_payload(m):
    out = u8(m["tag"]) + f32(m["gamma"]) + f32(m["beta"]) + f32(m["b"])
    out += u32(len(m["coef"])) + u32(len(m["rows"][0]))
    for c in m["coef"]:
        out += f32(c)
    for row in m["rows"]:
        nz = [(j, v) for j, v in enumerate(row) if v != 0.0]
        out += u32(len(nz))
        for j, v in nz:
            out += u32(j) + f32(v)
    return out


def approx_payload(a):
    out = u32(a["d"]) + f32(a["gamma"]) + f32(a["b"]) + f32(a["c"])
    out += f32(a["max_sv_norm_sq"])
    for v in a["v"]:
        out += f32(v)
    for row in a["m_upper"]:
        for v in row:
            out += f32(v)
    return out


def f16_svm_payload(m):
    out = u8(1) + u8(m["tag"]) + f32(m["gamma"]) + f32(m["beta"]) + f32(m["b"])
    out += u32(len(m["coef"])) + u32(len(m["rows"][0]))
    for c in m["coef"]:
        out += f16(c)
    for row in m["rows"]:
        nz = [(j, v) for j, v in enumerate(row) if v != 0.0]
        out += u32(len(nz))
        for j, v in nz:
            out += u32(j) + f16(v)
    return out


def f16_approx_payload(a):
    out = u8(2) + u32(a["d"]) + f32(a["gamma"]) + f32(a["b"]) + f32(a["c"])
    out += f32(a["max_sv_norm_sq"])
    for v in a["v"]:
        out += f16(v)
    for row in a["m_upper"]:
        for v in row:
            out += f16(v)
    return out


def int8_svm_payload(m):
    out = u8(1) + u8(m["tag"]) + f32(m["gamma"]) + f32(m["beta"]) + f32(m["b"])
    out += u32(len(m["coef"]["q"])) + u32(len(m["rows"][0]["q"]))
    out += f32(m["coef"]["scale"])
    for q in m["coef"]["q"]:
        out += i8(q)
    for row in m["rows"]:
        nz = [(j, q) for j, q in enumerate(row["q"]) if q != 0]
        out += u32(len(nz)) + f32(row["scale"])
        for j, q in nz:
            out += u32(j) + i8(q)
    return out


def int8_approx_payload(a):
    out = u8(2) + u32(a["d"]) + f32(a["gamma"]) + f32(a["b"]) + f32(a["c"])
    out += f32(a["max_sv_norm_sq"])
    out += f32(a["v"]["scale"])
    for q in a["v"]["q"]:
        out += i8(q)
    for row in a["m_upper"]:
        out += f32(row["scale"])
    for row in a["m_upper"]:
        for q in row["q"]:
            out += i8(q)
    return out


def rff_payload(r):
    out = u32(r["dim"]) + u32(len(r["w"])) + u64(r["seed"])
    out += f32(r["gamma"]) + f32(r["bias"]) + f32(r["err_est"])
    for v in r["w"]:
        out += f32(v)
    return out


# -- v2 payload builders (dense tensors, 64-byte intra-payload pads) -------


def f16_svm_payload_v2(m):
    out = u8(1) + u8(m["tag"]) + f32(m["gamma"]) + f32(m["beta"]) + f32(m["b"])
    out += u32(len(m["coef"])) + u32(len(m["rows"][0]))
    out = pad64(out)
    for c in m["coef"]:
        out += f16(c)
    out = pad64(out)
    for row in m["rows"]:  # dense row-major, zeros included
        for v in row:
            out += f16(v)
    return out


def f16_approx_payload_v2(a):
    out = u8(2) + u32(a["d"]) + f32(a["gamma"]) + f32(a["b"]) + f32(a["c"])
    out += f32(a["max_sv_norm_sq"])
    out = pad64(out)
    for v in a["v"]:
        out += f16(v)
    out = pad64(out)
    for row in a["m_upper"]:
        for v in row:
            out += f16(v)
    return out


def int8_svm_payload_v2(m):
    out = u8(1) + u8(m["tag"]) + f32(m["gamma"]) + f32(m["beta"]) + f32(m["b"])
    out += u32(len(m["coef"]["q"])) + u32(len(m["rows"][0]["q"]))
    out += f32(m["coef"]["scale"])
    out = pad64(out)
    for q in m["coef"]["q"]:
        out += i8(q)
    out = pad64(out)
    for row in m["rows"]:  # all per-row scales first...
        out += f32(row["scale"])
    out = pad64(out)
    for row in m["rows"]:  # ...then the dense row-major q block
        for q in row["q"]:
            out += i8(q)
    return out


def int8_approx_payload_v2(a):
    out = u8(2) + u32(a["d"]) + f32(a["gamma"]) + f32(a["b"]) + f32(a["c"])
    out += f32(a["max_sv_norm_sq"])
    out += f32(a["v"]["scale"])
    out = pad64(out)
    for q in a["v"]["q"]:
        out += i8(q)
    out = pad64(out)
    for row in a["m_upper"]:
        out += f32(row["scale"])
    out = pad64(out)
    for row in a["m_upper"]:
        for q in row["q"]:
            out += i8(q)
    return out


def rff_payload_v2(r):
    out = u32(r["dim"]) + u32(len(r["w"])) + u64(r["seed"])
    out += f32(r["gamma"]) + f32(r["bias"]) + f32(r["err_est"])
    out = pad64(out)
    for v in r["w"]:
        out += f32(v)
    return out


# -- fixtures --------------------------------------------------------------

FIXTURES = {
    "v1_svm.arbf": arbf(0, 3, 3, 0, [(1, svm_payload(SVM))]),
    "v1_approx.arbf": arbf(0, 3, 0, 0, [(2, approx_payload(APPROX))]),
    "v1_bundle_policy.arbf": arbf(
        7,
        3,
        3,
        FLAG_HAS_POLICY,
        [(1, svm_payload(SVM)), (2, approx_payload(APPROX)), (3, POLICY)],
    ),
    "v1_bundle_f16.arbf": arbf(
        3,
        3,
        3,
        FLAG_QUANT_F16,
        [(4, f16_svm_payload(SVM)), (4, f16_approx_payload(APPROX))],
    ),
    "v1_bundle_int8_policy.arbf": arbf(
        9,
        3,
        3,
        FLAG_QUANT_INT8 | FLAG_HAS_POLICY,
        [(5, int8_svm_payload(SVM8)), (5, int8_approx_payload(APPROX8)), (3, POLICY)],
    ),
    "v1_bundle_rff.arbf": arbf(
        11,
        3,
        3,
        FLAG_RFF,
        [(1, svm_payload(SVM)), (2, approx_payload(APPROX)), (6, rff_payload(RFF))],
    ),
    # v2 twins: same toy values and generations, zero-copy layout.
    # Kinds 1-3 reuse the v1 payload builders byte-for-byte; only the
    # record framing (header pad word) differs. Together the four
    # bundles cover record kinds 1-6 under the v2 framing.
    "v2_bundle_policy.arbf": arbf_v2(
        7,
        3,
        3,
        FLAG_HAS_POLICY,
        [(1, svm_payload(SVM)), (2, approx_payload(APPROX)), (3, POLICY)],
    ),
    "v2_bundle_f16.arbf": arbf_v2(
        3,
        3,
        3,
        FLAG_QUANT_F16,
        [(4, f16_svm_payload_v2(SVM)), (4, f16_approx_payload_v2(APPROX))],
    ),
    "v2_bundle_int8_policy.arbf": arbf_v2(
        9,
        3,
        3,
        FLAG_QUANT_INT8 | FLAG_HAS_POLICY,
        [
            (5, int8_svm_payload_v2(SVM8)),
            (5, int8_approx_payload_v2(APPROX8)),
            (3, POLICY),
        ],
    ),
    "v2_bundle_rff.arbf": arbf_v2(
        11,
        3,
        3,
        FLAG_RFF,
        [(1, svm_payload(SVM)), (2, approx_payload(APPROX)), (6, rff_payload_v2(RFF))],
    ),
}

if __name__ == "__main__":
    for name, data in FIXTURES.items():
        path = os.path.join(HERE, name)
        with open(path, "wb") as fh:
            fh.write(data)
        print(f"wrote {name}: {len(data)} bytes, crc32 {zlib.crc32(data):#010x}")
