//! Sharded serving plane integration (the PR-3 acceptance tests):
//!
//! * **determinism** — an `n`-shard coordinator returns bit-identical
//!   decisions, routes and generations to `shards(1)` for a mixed
//!   exact/approx tenant set, because every model's batches land on
//!   exactly one shard and routing is per-model state;
//! * **placement** — rendezvous placement is deterministic, in range,
//!   spreads tenants, and is stable under tenant add/remove (a
//!   tenant's shard is a pure function of its id and the shard count,
//!   never of the tenant set);
//! * **hot swap** — a mid-stream republish is picked up by the owning
//!   shard (via the async prefetch path, no explicit refresh) without
//!   a single errored or dropped in-flight request;
//! * **metrics** — per-model rows aggregate across shard sinks with
//!   sum semantics and report the owning shard.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::ApproxModel;
use approxrbf::coordinator::shard::assign;
use approxrbf::coordinator::{
    Coordinator, Route, RoutePolicy, TenantPolicy,
};
use approxrbf::data::{synth, Dataset, UnitNormScaler};
use approxrbf::linalg::{quantblas, MathBackend};
use approxrbf::prop_cases;
use approxrbf::registry::quant::TenantModels;
use approxrbf::registry::{
    FormatVersion, ModelStore, PayloadKind, PublishOptions, Substrate,
};
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};
use approxrbf::util::Rng;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("approxrbf_shard_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn trained_pair(
    seed: u64,
    gamma_mult: f32,
) -> (SvmModel, ApproxModel, Dataset) {
    let ds = synth::two_gaussians(seed, 220, 8, 1.5);
    let scaled = UnitNormScaler.apply_dataset(&ds);
    let gamma = gamma_max_for_data(&scaled) * gamma_mult;
    let (model, _) =
        train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
    (model, am, scaled)
}

/// A mixed tenant set: one policy-pinned AlwaysExact tenant, one
/// in-bound hybrid tenant, one hybrid tenant whose traffic is partly
/// pushed out of bound (exact escorts). Returns (store, test data per
/// tenant id).
fn mixed_registry(
    tag: &str,
) -> (Arc<ModelStore>, Vec<(&'static str, Dataset)>) {
    let store = Arc::new(ModelStore::open(temp_dir(tag)).unwrap());
    let (m1, a1, d1) = trained_pair(101, 0.8);
    let (m2, a2, d2) = trained_pair(202, 0.8);
    let (m3, a3, d3) = trained_pair(303, 0.8);
    // Payloads pinned to f32: these tests assert a specific
    // approx/exact route mix, which a quantized payload's folded drift
    // budget could legitimately shift (the dedicated quant tests below
    // cover quantized tenants with an explicit tolerance).
    store
        .publish_with(
            "pinned-exact",
            &m1,
            &a1,
            PublishOptions {
                policy: Some(TenantPolicy {
                    route: Some(RoutePolicy::AlwaysExact),
                    ..Default::default()
                }),
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    let f32_opts = || PublishOptions {
        quantize: Some(PayloadKind::F32),
        ..Default::default()
    };
    store
        .publish_with("hybrid-in", &m2, &a2, f32_opts())
        .unwrap();
    store
        .publish_with("hybrid-mixed", &m3, &a3, f32_opts())
        .unwrap();
    (
        store,
        vec![
            ("pinned-exact", d1),
            ("hybrid-in", d2),
            ("hybrid-mixed", d3),
        ],
    )
}

/// Deterministic mixed-tenant traffic: (tenant id, features) tuples;
/// a third of `hybrid-mixed`'s rows are scaled out of bound.
fn build_traffic(
    tenants: &[(&'static str, Dataset)],
    n: usize,
) -> Vec<(&'static str, Vec<f32>)> {
    let mut rng = Rng::new(0x51AD);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let (id, ds) = &tenants[i % tenants.len()];
        let row = (i / tenants.len()) % ds.len();
        let mut z = ds.x.row(row).to_vec();
        if *id == "hybrid-mixed" && rng.chance(0.33) {
            let s = rng.range(2.5, 5.0) as f32;
            for v in &mut z {
                *v *= s;
            }
        }
        out.push((*id, z));
    }
    out
}

/// One served request: (model, generation, decision bits, route).
type Served = (String, u64, u32, Route);

/// Serve `traffic` through an `n`-shard plane; returns per-request
/// [`Served`] rows in submission order plus the aggregated snapshot.
fn run_plane(
    store: &Arc<ModelStore>,
    traffic: &[(&'static str, Vec<f32>)],
    shards: usize,
) -> (Vec<Served>, approxrbf::coordinator::MetricsSnapshot) {
    // Generous drift tolerance so quantized tenants in these
    // workloads stay on the fast path deterministically; a no-op
    // for f32 tenants (no quant error to fold).
    run_plane_tol(store, traffic, shards, 1.0)
}

fn run_plane_tol(
    store: &Arc<ModelStore>,
    traffic: &[(&'static str, Vec<f32>)],
    shards: usize,
    quant_drift_tol: f32,
) -> (Vec<Served>, approxrbf::coordinator::MetricsSnapshot) {
    let coord = Coordinator::builder()
        .shards(shards)
        .max_wait(Duration::from_millis(1))
        .quant_drift_tol(quant_drift_tol)
        .start_registry(store.clone())
        .unwrap();
    assert_eq!(coord.shard_count(), shards);
    let client = coord.client();
    let mut session = client.session();
    for (id, z) in traffic {
        session.submit_to(id, z.clone()).unwrap();
    }
    let completions = session.wait_all(Duration::from_secs(60)).unwrap();
    let rows = completions
        .into_iter()
        .map(|c| {
            let r = c.expect("no failures in the determinism workload");
            (r.model.to_string(), r.generation, r.decision.to_bits(), r.route)
        })
        .collect();
    let snap = coord.metrics();
    coord.shutdown().unwrap();
    (rows, snap)
}

#[test]
fn sharded_plane_is_decision_identical_to_single_shard() {
    let (store, tenants) = mixed_registry("determinism");
    let traffic = build_traffic(&tenants, 360);
    let (r1, s1) = run_plane(&store, &traffic, 1);
    let (r3, s3) = run_plane(&store, &traffic, 3);
    assert_eq!(r1.len(), r3.len());
    for (i, (a, b)) in r1.iter().zip(&r3).enumerate() {
        assert_eq!(a, b, "request {i} differs between 1 and 3 shards");
    }
    // The workload actually exercised both routes (mixed tenant set).
    assert!(r1.iter().any(|(_, _, _, route)| *route == Route::Exact));
    assert!(r1.iter().any(|(_, _, _, route)| *route == Route::Approx));
    // Aggregated totals agree; per-model rows sum to the same counts.
    assert_eq!(s1.served_approx, s3.served_approx);
    assert_eq!(s1.served_exact, s3.served_exact);
    assert_eq!(s1.dropped, 0);
    assert_eq!(s3.dropped, 0);
    assert_eq!(s3.shard_count, 3);
    assert_eq!(s3.per_model.len(), 3);
    for m in &s3.per_model {
        // Rendezvous placement: exactly one owning shard per model.
        assert_eq!(
            m.shards.len(),
            1,
            "'{}' served by shards {:?}",
            m.id,
            m.shards
        );
        assert_eq!(m.shards[0], assign(&m.id, 3));
        let single = s1
            .per_model
            .iter()
            .find(|x| x.id == m.id)
            .expect("same tenant set");
        assert_eq!(single.served_total(), m.served_total());
        assert_eq!(single.out_of_bound, m.out_of_bound);
    }
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn property_rendezvous_placement_is_stable_and_spread() {
    prop_cases!("rendezvous placement", 32, |rng| {
        let n_shards = 1 + rng.below(8);
        let n_tenants = 8 + rng.below(56);
        let ids: Vec<String> = (0..n_tenants)
            .map(|i| format!("tenant-{i}-{}", rng.below(10_000)))
            .collect();
        let before: Vec<usize> =
            ids.iter().map(|id| assign(id, n_shards)).collect();
        for &s in &before {
            assert!(s < n_shards);
        }
        // Placement is a pure function of (id, shard count): evaluating
        // other tenants ("add"), or a subset ("remove"), cannot move
        // anyone. This is the property a sorted-mod-N scheme violates.
        let _ = assign("an-added-tenant", n_shards);
        let subset: Vec<usize> = ids
            .iter()
            .step_by(2)
            .map(|id| assign(id, n_shards))
            .collect();
        let after: Vec<usize> =
            ids.iter().map(|id| assign(id, n_shards)).collect();
        assert_eq!(before, after, "placement moved under add/remove");
        assert_eq!(
            subset,
            before.iter().copied().step_by(2).collect::<Vec<_>>()
        );
        // Spread smoke test: with ≥ 16 tenants per shard expected,
        // no shard may own nothing (deterministic seeds; the chance of
        // a legitimately empty shard at this load is ~1e-7).
        if n_shards > 1 && n_tenants >= 16 * n_shards {
            let mut counts = vec![0usize; n_shards];
            for &s in &before {
                counts[s] += 1;
            }
            assert!(
                counts.iter().all(|&c| c > 0),
                "empty shard: {counts:?}"
            );
        }
    });
}

#[test]
fn mid_stream_republish_swaps_on_owning_shard_without_errors() {
    let (store, tenants) = mixed_registry("hotswap");
    // Fast poll so the async prefetch path (no explicit refresh) picks
    // the republish up within the test's deadline.
    let coord = Coordinator::builder()
        .shards(3)
        .max_wait(Duration::from_millis(1))
        .swap_poll(Duration::from_millis(5))
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    let swap_id = "hybrid-in";
    let ds = &tenants.iter().find(|(id, _)| *id == swap_id).unwrap().1;

    // Phase A: traffic against generation 1.
    let mut responses = Vec::new();
    for i in 0..120 {
        client.submit_to(swap_id, ds.x.row(i % ds.len()).to_vec()).unwrap();
    }
    while responses.len() < 40 {
        let r = client
            .recv(Duration::from_secs(10))
            .expect("lost response before swap")
            .expect("no errors before swap");
        responses.push(r);
    }

    // Phase B: republish mid-stream, NO refresh() — the owning shard's
    // swap poll must detect it, prefetch-decode off the hot path, and
    // swap atomically.
    let (m2, a2, _) = trained_pair(909, 0.7);
    assert_eq!(store.publish(swap_id, &m2, &a2).unwrap(), 2);
    // Reference the served generation-2 state (quantized when
    // APPROXRBF_TEST_QUANT is set).
    let gen2 = store.load(swap_id).unwrap();

    // Phase C: keep streaming until generation 2 serves, bounded by a
    // deadline; every completion must be Ok throughout.
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut seen_gen2 = false;
    let mut submitted = 120u64;
    while !seen_gen2 {
        assert!(
            Instant::now() < deadline,
            "prefetch swap never landed ({} responses so far)",
            responses.len()
        );
        client
            .submit_to(
                swap_id,
                ds.x.row(submitted as usize % ds.len()).to_vec(),
            )
            .unwrap();
        submitted += 1;
        while let Some(c) = client.recv(Duration::from_millis(20)) {
            let r = c.expect("no errors across the prefetch swap");
            seen_gen2 |= r.generation == 2;
            responses.push(r);
        }
    }
    // Drain what is still in flight; nothing may error or go missing.
    while (responses.len() as u64) < submitted {
        let r = client
            .recv(Duration::from_secs(10))
            .expect("lost in-flight response across the swap")
            .expect("no errors across the prefetch swap");
        responses.push(r);
    }
    let mut ids = std::collections::HashSet::new();
    let mut gens = [0usize; 3];
    for r in &responses {
        assert!(ids.insert(r.id), "duplicate completion {}", r.id);
        gens[r.generation as usize] += 1;
        // Correctness per generation: no torn state across the swap.
        let want2 =
            gen2.approx_decision_one(ds.x.row(r.id as usize % ds.len()));
        if r.generation == 2 && r.route == Route::Approx {
            assert!((r.decision - want2).abs() < 1e-3);
        }
    }
    assert!(gens[1] > 0, "generation 1 never served");
    assert!(gens[2] > 0, "generation 2 never served");
    let snap = coord.metrics();
    assert_eq!(snap.dropped, 0, "hot swap dropped requests");
    let row = snap
        .per_model
        .iter()
        .find(|m| m.id == swap_id)
        .expect("tenant metrics row");
    assert_eq!(row.shards, vec![assign(swap_id, 3)]);
    coord.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn quantized_tenant_is_shard_invariant_and_within_bound_of_f32_twin() {
    // An int8 tenant and its f32 twin (same trained weights) served
    // side by side: shards(4) must be bit-identical to shards(1) for
    // BOTH, and the int8 tenant's approx-routed decisions must stay
    // within the reported quantization bound of the twin's.
    let store = Arc::new(ModelStore::open(temp_dir("quantparity")).unwrap());
    let (m, a, ds) = trained_pair(404, 0.8);
    store
        .publish_with(
            "twin-f32",
            &m,
            &a,
            PublishOptions {
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    store
        .publish_with(
            "quant-int8",
            &m,
            &a,
            PublishOptions {
                quantize: Some(PayloadKind::Int8),
                ..Default::default()
            },
        )
        .unwrap();
    let q_entry = store.load("quant-int8").unwrap();
    let q = q_entry.quant_info().expect("int8 quant info");
    let tenants: Vec<(&'static str, Dataset)> =
        vec![("twin-f32", ds.clone()), ("quant-int8", ds)];
    let traffic = build_traffic(&tenants, 240);
    let (r1, s1) = run_plane(&store, &traffic, 1);
    let (r4, s4) = run_plane(&store, &traffic, 4);
    assert_eq!(r1.len(), r4.len());
    for (i, (a1, b4)) in r1.iter().zip(&r4).enumerate() {
        assert_eq!(a1, b4, "request {i} differs between 1 and 4 shards");
    }
    assert_eq!(s1.served_approx, s4.served_approx);
    assert_eq!(s1.served_exact, s4.served_exact);
    assert_eq!(s1.dropped + s4.dropped, 0);
    // Bound check: pair responses by traffic index (tenants alternate).
    let mut approx_pairs = 0;
    for (i, (id, z)) in traffic.iter().enumerate() {
        if *id != "quant-int8" {
            continue;
        }
        let (_, _, bits, route) = &r1[i];
        if *route != Route::Approx {
            continue;
        }
        approx_pairs += 1;
        let dec = f32::from_bits(*bits);
        let (f32_dec, zn) = a.decision_one(z);
        assert!(
            (dec - f32_dec).abs() <= q.approx_err.decision_error(zn),
            "request {i}: int8 drift beyond reported bound"
        );
    }
    assert!(approx_pairs > 0, "int8 tenant never exercised approx route");
    // Kernel-arm invariance: the served int8 bits equal every dispatch
    // arm's native evaluation (exact integer accumulation makes int8
    // decisions arm-independent), so the plane's decisions cannot
    // depend on which kernel arm a node selects. CI re-runs this whole
    // file under APPROXRBF_QUANT_KERNEL=blocked as the process-level
    // counterpart of this in-process check.
    let (q_exact, q_approx) = match &q_entry.models {
        TenantModels::Quantized { exact, approx } => (exact, approx),
        TenantModels::F32 { .. } => panic!("int8 entry decoded as f32"),
    };
    for (i, (id, z)) in traffic.iter().enumerate() {
        if *id != "quant-int8" {
            continue;
        }
        let (_, _, bits, route) = &r1[i];
        for arm in quantblas::available_arms() {
            let want = match route {
                Route::Approx => q_approx.decision_one_with(arm, z).0,
                Route::Exact => q_exact.decision_one_with(arm, z),
            };
            assert_eq!(
                want.to_bits(),
                *bits,
                "request {i}: served bits differ from arm {arm}"
            );
        }
    }
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn v2_mapped_tenant_is_shard_invariant_and_bit_identical_to_v1_twin() {
    // The zero-copy acceptance on the sharded plane: the same trained
    // int8 weights published at format v1 (heap) and v2 (mapped) serve
    // request-for-request identical decision bits, and both tenants are
    // bit-identical between shards(1) and shards(4).
    let store = Arc::new(ModelStore::open(temp_dir("v2parity")).unwrap());
    let (m, a, ds) = trained_pair(707, 0.8);
    let opts = |format| PublishOptions {
        quantize: Some(PayloadKind::Int8),
        format: Some(format),
        ..Default::default()
    };
    store
        .publish_with("zc-v1", &m, &a, opts(FormatVersion::V1))
        .unwrap();
    store
        .publish_with("zc-v2", &m, &a, opts(FormatVersion::V2))
        .unwrap();
    // The entries differ in storage, never in values.
    let e1 = store.load("zc-v1").unwrap();
    let e2 = store.load("zc-v2").unwrap();
    assert_eq!(e1.mapped_bytes(), 0);
    if cfg!(target_endian = "little") {
        assert!(e2.mapped_bytes() > 0, "v2 int8 entry must map its tensors");
        assert!(e2.heap_bytes() < e1.heap_bytes(), "v2 must shed heap");
    }
    let tenants: Vec<(&'static str, Dataset)> =
        vec![("zc-v1", ds.clone()), ("zc-v2", ds)];
    let traffic = build_traffic(&tenants, 240);
    let (r1, _) = run_plane(&store, &traffic, 1);
    let (r4, s4) = run_plane(&store, &traffic, 4);
    assert_eq!(r1.len(), r4.len());
    for (i, (a1, b4)) in r1.iter().zip(&r4).enumerate() {
        assert_eq!(a1, b4, "request {i} differs between 1 and 4 shards");
    }
    // build_traffic alternates the tenants over the same rows, so pair
    // (2k, 2k+1) carries identical features: the twins must answer with
    // the same decision bits, request for request.
    for k in 0..traffic.len() / 2 {
        let (id_a, gen_a, bits_a, route_a) = &r1[2 * k];
        let (id_b, gen_b, bits_b, route_b) = &r1[2 * k + 1];
        assert_eq!((id_a.as_str(), *gen_a), ("zc-v1", 1));
        assert_eq!((id_b.as_str(), *gen_b), ("zc-v2", 1));
        assert_eq!(route_a, route_b, "pair {k}: v1/v2 route drift");
        assert_eq!(bits_a, bits_b, "pair {k}: v1/v2 decision drift");
    }
    // The aggregated snapshot carries the residency gauges: the mapped
    // tenant's row sheds heap onto mapped_bytes, the v1 twin's doesn't.
    let row = |id: &str| {
        s4.per_model
            .iter()
            .find(|m| m.id == id)
            .unwrap_or_else(|| panic!("no metrics row for {id}"))
    };
    assert_eq!(row("zc-v1").mapped_bytes, 0);
    assert!(row("zc-v1").heap_bytes > 0);
    if cfg!(target_endian = "little") {
        assert!(row("zc-v2").mapped_bytes > 0);
        assert!(row("zc-v2").heap_bytes < row("zc-v1").heap_bytes);
    }
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn mid_stream_f32_to_int8_republish_swaps_via_prefetch() {
    // Payload-kind change across a hot swap, through the async
    // prefetch path (no refresh): generation 1 serves f32, the
    // republish switches the SAME tenant to int8, and the owning shard
    // swaps without one errored or dropped request.
    let store = Arc::new(ModelStore::open(temp_dir("quantswap")).unwrap());
    let (m, a, ds) = trained_pair(505, 0.8);
    store
        .publish_with(
            "tenant",
            &m,
            &a,
            PublishOptions {
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    let coord = Coordinator::builder()
        .shards(4)
        .max_wait(Duration::from_millis(1))
        .swap_poll(Duration::from_millis(5))
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    let mut responses = Vec::new();
    for i in 0..100 {
        client
            .submit_to("tenant", ds.x.row(i % ds.len()).to_vec())
            .unwrap();
    }
    while responses.len() < 30 {
        let r = client
            .recv(Duration::from_secs(10))
            .expect("lost response before swap")
            .expect("no errors before swap");
        assert_eq!(r.generation, 1);
        responses.push(r);
    }
    // The payload-kind flip, mid-stream, no refresh().
    store
        .publish_with(
            "tenant",
            &m,
            &a,
            PublishOptions {
                quantize: Some(PayloadKind::Int8),
                ..Default::default()
            },
        )
        .unwrap();
    let int8_entry = store.load("tenant").unwrap();
    assert_eq!(int8_entry.payload(), PayloadKind::Int8);
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut submitted = 100u64;
    let mut seen_gen2 = false;
    while !seen_gen2 {
        assert!(
            Instant::now() < deadline,
            "int8 prefetch swap never landed"
        );
        client
            .submit_to(
                "tenant",
                ds.x.row(submitted as usize % ds.len()).to_vec(),
            )
            .unwrap();
        submitted += 1;
        while let Some(c) = client.recv(Duration::from_millis(20)) {
            let r = c.expect("no errors across the payload-kind swap");
            seen_gen2 |= r.generation == 2;
            responses.push(r);
        }
    }
    while (responses.len() as u64) < submitted {
        let r = client
            .recv(Duration::from_secs(10))
            .expect("lost in-flight response across the swap")
            .expect("no errors across the payload-kind swap");
        responses.push(r);
    }
    // Generation-2 responses came off the native int8 storage.
    let mut gen2_checked = 0;
    for r in &responses {
        if r.generation != 2 {
            continue;
        }
        let z = ds.x.row(r.id as usize % ds.len());
        let want = match r.route {
            Route::Approx => int8_entry.approx_decision_one(z),
            Route::Exact => int8_entry.exact_decision_one(z),
        };
        assert!((r.decision - want).abs() < 1e-3);
        gen2_checked += 1;
    }
    assert!(gen2_checked > 0, "generation 2 never served");
    assert_eq!(coord.metrics().dropped, 0);
    coord.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn rff_tenant_rescues_large_gamma_workload_and_is_shard_invariant() {
    // The PR-7 acceptance workload: one trained model at γ = 6·γ_MAX
    // on unit-norm data. The Maclaurin Eq. 3.11 budget collapses to
    // ~1/36 ≪ ‖z‖² ≈ 1, so the maclaurin-substrate twin escorts
    // (essentially) everything to exact; the rff-substrate twin has no
    // ‖z‖²-shaped validity region and serves the same workload on the
    // fast path, within its stored error estimate — and both must stay
    // bit-identical between shards(1) and shards(4).
    let store = Arc::new(ModelStore::open(temp_dir("rffrescue")).unwrap());
    let (m, a, ds) = trained_pair(606, 6.0);
    store
        .publish_with(
            "big-gamma-mac",
            &m,
            &a,
            PublishOptions {
                substrate: Some(Substrate::Maclaurin),
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    store
        .publish_with(
            "big-gamma-rff",
            &m,
            &a,
            PublishOptions {
                substrate: Some(Substrate::Rff),
                rff_features: Some(2048),
                ..Default::default()
            },
        )
        .unwrap();
    let rff_entry = store.load("big-gamma-rff").unwrap();
    let err_est = rff_entry.models.rff().expect("rff entry").err_est;
    assert!(err_est.is_finite() && err_est > 0.0);
    // Tolerance above the stored estimate so the all-or-nothing gate
    // opens for the rff tenant; the maclaurin twin's f32 budget is
    // tolerance-independent, so it keeps escorting regardless.
    let tol = (err_est * 1.25).max(1.0);
    let tenants: Vec<(&'static str, Dataset)> =
        vec![("big-gamma-mac", ds.clone()), ("big-gamma-rff", ds)];
    let traffic = build_traffic(&tenants, 240);
    let (r1, s1) = run_plane_tol(&store, &traffic, 1, tol);
    let (r4, s4) = run_plane_tol(&store, &traffic, 4, tol);
    assert_eq!(r1.len(), r4.len());
    for (i, (a1, b4)) in r1.iter().zip(&r4).enumerate() {
        assert_eq!(a1, b4, "request {i} differs between 1 and 4 shards");
    }
    assert_eq!(s1.served_approx, s4.served_approx);
    assert_eq!(s1.served_exact, s4.served_exact);
    assert_eq!(s1.dropped + s4.dropped, 0);
    // Route mix per tenant: the Maclaurin twin escorts ≳90%, the rff
    // twin serves ≳90% approx (both are 100% for this workload, but
    // the acceptance floor is what the issue pins).
    let mut counts: HashMap<&str, (u64, u64)> = HashMap::new();
    for (id, _, _, route) in &r1 {
        let c = counts.entry(id.as_str()).or_default();
        match route {
            Route::Approx => c.0 += 1,
            Route::Exact => c.1 += 1,
        }
    }
    let (mac_a, mac_e) = counts["big-gamma-mac"];
    let (rff_a, rff_e) = counts["big-gamma-rff"];
    assert!(
        mac_e as f64 >= 0.9 * (mac_a + mac_e) as f64,
        "maclaurin twin escorted only {mac_e}/{} at 6·γ_MAX",
        mac_a + mac_e
    );
    assert!(
        rff_a as f64 >= 0.9 * (rff_a + rff_e) as f64,
        "rff twin escorted {rff_e}/{} despite err_est {err_est} ≤ tol {tol}",
        rff_a + rff_e
    );
    // Served approx decisions stay within the stored estimate of the
    // exact reference, and equal the native rff evaluation bit-for-bit.
    let mut checked = 0;
    for (i, (id, z)) in traffic.iter().enumerate() {
        if *id != "big-gamma-rff" {
            continue;
        }
        let (_, _, bits, route) = &r1[i];
        if *route != Route::Approx {
            continue;
        }
        checked += 1;
        let dec = f32::from_bits(*bits);
        let exact = rff_entry.exact_decision_one(z);
        assert!(
            (dec - exact).abs() <= err_est,
            "request {i}: |{dec} - {exact}| beyond stored estimate {err_est}"
        );
        assert_eq!(
            rff_entry.approx_decision_one(z).to_bits(),
            *bits,
            "request {i}: served bits differ from native rff evaluation"
        );
    }
    assert!(checked > 0, "rff tenant never exercised the approx route");
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn per_shard_metrics_fan_in_sums_per_model() {
    // End-to-end companion to the unit regression test: serve three
    // tenants on a 4-shard plane, then check the aggregated snapshot
    // accounts every request exactly once under the right model row.
    let (store, tenants) = mixed_registry("metrics");
    let coord = Coordinator::builder()
        .shards(4)
        .max_wait(Duration::from_millis(1))
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    let mut want: HashMap<&str, u64> = HashMap::new();
    for (i, (id, ds)) in tenants.iter().enumerate() {
        let rows = 20 + 10 * i;
        let mut session = client.session();
        for r in 0..rows {
            session.submit_to(id, ds.x.row(r % ds.len()).to_vec()).unwrap();
        }
        let completions =
            session.wait_all(Duration::from_secs(30)).unwrap();
        assert!(completions.iter().all(|c| c.is_ok()));
        *want.entry(*id).or_default() += rows as u64;
    }
    let snap = coord.metrics();
    assert_eq!(snap.shard_count, 4);
    let mut total = 0;
    for m in &snap.per_model {
        assert_eq!(
            m.served_total(),
            want[m.id.as_str()],
            "model '{}' lost counts in fan-in",
            m.id
        );
        assert_eq!(m.shards, vec![assign(&m.id, 4)]);
        total += m.served_total();
    }
    assert_eq!(total, snap.served_approx + snap.served_exact);
    coord.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(store.root());
}
