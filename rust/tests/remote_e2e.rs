//! Network serving tier e2e (the PR-6 acceptance tests): a router over
//! **two real shard-server processes** (spawned from the compiled
//! `approxrbf` binary) serving a mixed exact/approx/int8 tenant set.
//!
//! * **bit-identity** — decisions, routes and generations served over
//!   the wire equal an in-process `shards(1)` plane on the same
//!   registry and traffic, request for request;
//! * **hot swap over the wire** — a mid-stream republish (picked up via
//!   the router's `Refresh` control frame) serves the new generation
//!   with zero dropped or errored in-flight requests;
//! * **fail-fast isolation** — killing one shard process turns that
//!   shard's tenants' requests into typed `PredictError`s (no client
//!   hang) while the surviving shard's tenants keep serving;
//! * **rollback over the wire** — `ModelStore::rollback` plus
//!   `Router::refresh` restores a previous generation's exact decision
//!   bits on the remote plane, generation for generation with a local
//!   one.
//!
//! Gated by `APPROXRBF_TEST_REMOTE=1` (spawns processes and binds
//! loopback sockets); each test is a silent pass without it. CI runs
//! the suite in the dedicated `tier1-remote` job (`make test-remote`).
//! All waits derive from `APPROXRBF_TEST_DEADLINE_MS` (see
//! `tests/common/mod.rs`).

mod common;

use std::io::BufRead;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use approxrbf::coordinator::{
    PredictErrorKind, Route, RoutePolicy, TenantPolicy,
};
use approxrbf::data::Dataset;
use approxrbf::net::{Router, RouterConfig};
use approxrbf::registry::{
    FormatVersion, ModelStore, PayloadKind, PublishOptions, Substrate,
};
use approxrbf::util::Rng;

use common::{run_in_process, temp_dir, trained_pair, Served, DRIFT_TOL};

fn remote_enabled() -> bool {
    match std::env::var("APPROXRBF_TEST_REMOTE") {
        Ok(v) => v == "1",
        Err(_) => false,
    }
}

/// A mixed tenant set with every serving mode: a policy-pinned
/// AlwaysExact tenant, two hybrid f32 tenants (one partly pushed out of
/// bound by the traffic generator), a native-int8 tenant, a
/// random-feature tenant, and a format-v2 int8 tenant the shard
/// processes serve from mapped bytes.
fn mixed_registry(
    tag: &str,
) -> (Arc<ModelStore>, Vec<(&'static str, Dataset)>) {
    let store = Arc::new(ModelStore::open(temp_dir(tag)).unwrap());
    let (m1, a1, d1) = trained_pair(101, 0.8);
    let (m2, a2, d2) = trained_pair(202, 0.8);
    let (m3, a3, d3) = trained_pair(303, 0.8);
    let (m4, a4, d4) = trained_pair(404, 0.8);
    let (m5, a5, d5) = trained_pair(505, 0.8);
    let (m6, a6, d6) = trained_pair(606, 0.8);
    store
        .publish_with(
            "pinned-exact",
            &m1,
            &a1,
            PublishOptions {
                policy: Some(TenantPolicy {
                    route: Some(RoutePolicy::AlwaysExact),
                    ..Default::default()
                }),
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    let f32_opts = || PublishOptions {
        quantize: Some(PayloadKind::F32),
        ..Default::default()
    };
    store.publish_with("hybrid-in", &m2, &a2, f32_opts()).unwrap();
    store.publish_with("hybrid-mixed", &m3, &a3, f32_opts()).unwrap();
    store
        .publish_with(
            "quant-int8",
            &m4,
            &a4,
            PublishOptions {
                quantize: Some(PayloadKind::Int8),
                ..Default::default()
            },
        )
        .unwrap();
    // Seed determinism makes the remote/in-process comparison exact for
    // this tenant: both planes regenerate the same W, φ from the seed.
    store
        .publish_with(
            "subst-rff",
            &m5,
            &a5,
            PublishOptions {
                substrate: Some(Substrate::Rff),
                rff_features: Some(1024),
                ..Default::default()
            },
        )
        .unwrap();
    // The zero-copy tenant: the shard processes decode this bundle over
    // a memory map and serve borrowed tensor views — decisions must
    // still be bit-identical to the in-process (equally mapped) plane.
    store
        .publish_with(
            "zc-v2-int8",
            &m6,
            &a6,
            PublishOptions {
                quantize: Some(PayloadKind::Int8),
                format: Some(FormatVersion::V2),
                ..Default::default()
            },
        )
        .unwrap();
    (
        store,
        vec![
            ("pinned-exact", d1),
            ("hybrid-in", d2),
            ("hybrid-mixed", d3),
            ("quant-int8", d4),
            ("subst-rff", d5),
            ("zc-v2-int8", d6),
        ],
    )
}

/// Deterministic mixed-tenant traffic; a third of `hybrid-mixed`'s rows
/// are scaled out of bound (exact escorts).
fn build_traffic(
    tenants: &[(&'static str, Dataset)],
    n: usize,
) -> Vec<(&'static str, Vec<f32>)> {
    let mut rng = Rng::new(0x51AD);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let (id, ds) = &tenants[i % tenants.len()];
        let row = (i / tenants.len()) % ds.len();
        let mut z = ds.x.row(row).to_vec();
        if *id == "hybrid-mixed" && rng.chance(0.33) {
            let s = rng.range(2.5, 5.0) as f32;
            for v in &mut z {
                *v *= s;
            }
        }
        out.push((*id, z));
    }
    out
}

/// One `approxrbf serve-shard` child process; killed on drop.
struct ShardProc {
    child: Child,
    addr: String,
}

impl ShardProc {
    fn spawn(store: &std::path::Path, shard_id: usize) -> ShardProc {
        let mut child = Command::new(env!("CARGO_BIN_EXE_approxrbf"))
            .args([
                "serve-shard",
                "--listen",
                "127.0.0.1:0",
                "--store",
                store.to_str().unwrap(),
                "--shard-id",
                &shard_id.to_string(),
                "--drift-tol",
                DRIFT_TOL,
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn shard server process");
        // The server prints `shard N serving on ADDR (...)` once bound;
        // scrape the resolved ephemeral port from it.
        let stdout = child.stdout.take().expect("child stdout");
        let mut line = String::new();
        std::io::BufReader::new(stdout)
            .read_line(&mut line)
            .expect("read shard server banner");
        let addr = line
            .split(" serving on ")
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .unwrap_or_else(|| panic!("unparseable banner: {line:?}"))
            .to_string();
        ShardProc { child, addr }
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ShardProc {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Two shard processes over `store` plus a connected router.
fn spawn_plane(store: &Arc<ModelStore>) -> (Vec<ShardProc>, Router) {
    let shards: Vec<ShardProc> = (0..2)
        .map(|i| ShardProc::spawn(store.root(), i))
        .collect();
    let addrs: Vec<String> =
        shards.iter().map(|s| s.addr.clone()).collect();
    let router = Router::connect(&addrs, RouterConfig::default())
        .expect("router connects to both shard processes");
    (shards, router)
}

#[test]
fn remote_plane_is_bit_identical_to_in_process() {
    if !remote_enabled() {
        eprintln!("skipping: APPROXRBF_TEST_REMOTE != 1");
        return;
    }
    let (store, tenants) = mixed_registry("identity");
    let traffic = build_traffic(&tenants, 240);
    let baseline = run_in_process(&store, &traffic);

    let (_shards, router) = spawn_plane(&store);
    // Every tenant's dimension came over in the handshake.
    let dims = router.model_dims();
    for (id, ds) in &tenants {
        assert_eq!(dims.get(*id).copied(), Some(ds.dim() as u32));
    }
    let client = router.client();
    let mut session = client.session();
    for (id, z) in &traffic {
        session.submit_to(id, z.clone()).unwrap();
    }
    let completions = session.wait_all(common::long_deadline()).unwrap();
    assert_eq!(completions.len(), baseline.len());
    let mut by_route = [0usize; 2];
    for (i, (c, want)) in completions.iter().zip(&baseline).enumerate() {
        let r = c.as_ref().expect("no failures over the wire");
        let got: Served = (
            r.model.to_string(),
            r.generation,
            r.decision.to_bits(),
            r.route,
        );
        assert_eq!(
            &got, want,
            "request {i}: remote decision differs from in-process"
        );
        by_route[(r.route == Route::Exact) as usize] += 1;
    }
    // The workload really exercised both routes and the non-f32
    // substrates.
    assert!(by_route[0] > 0 && by_route[1] > 0);
    assert!(baseline.iter().any(|(m, _, _, _)| m == "quant-int8"));
    assert!(baseline.iter().any(|(m, _, _, _)| m == "subst-rff"));
    assert!(baseline.iter().any(|(m, _, _, _)| m == "zc-v2-int8"));

    // Remote metrics fan-in accounts every request exactly once.
    let snap = router.metrics();
    assert_eq!(
        snap.served_approx + snap.served_exact,
        traffic.len() as u64
    );
    assert_eq!(snap.dropped, 0);
    assert_eq!(snap.per_model.len(), tenants.len());
    router.shutdown();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn mid_stream_republish_over_the_wire_drops_nothing() {
    if !remote_enabled() {
        eprintln!("skipping: APPROXRBF_TEST_REMOTE != 1");
        return;
    }
    let (store, tenants) = mixed_registry("hotswap");
    let (_shards, router) = spawn_plane(&store);
    let client = router.client();
    let swap_id = "hybrid-in";
    let ds = &tenants.iter().find(|(id, _)| *id == swap_id).unwrap().1;

    // Phase A: traffic against generation 1.
    let mut responses = Vec::new();
    for i in 0..120 {
        client
            .submit_to(swap_id, ds.x.row(i % ds.len()).to_vec())
            .unwrap();
    }
    while responses.len() < 40 {
        let r = client
            .recv(common::recv_deadline())
            .expect("lost response before swap")
            .expect("no errors before swap");
        assert_eq!(r.generation, 1);
        responses.push(r);
    }

    // Phase B: republish mid-stream, then nudge the shard processes
    // over the wire (the Refresh control frame — the remote counterpart
    // of Coordinator::refresh).
    let (m2, a2, _) = trained_pair(909, 0.7);
    assert_eq!(store.publish(swap_id, &m2, &a2).unwrap(), 2);
    assert_eq!(router.refresh().unwrap(), 2, "both shards must ack");

    // Phase C: stream until generation 2 serves; every in-flight and
    // new completion must be Ok throughout — zero drops, zero errors.
    let deadline = Instant::now() + common::deadline();
    let mut submitted = 120u64;
    let mut seen_gen2 = false;
    while !seen_gen2 {
        assert!(
            Instant::now() < deadline,
            "generation 2 never served over the wire \
             ({} responses so far)",
            responses.len()
        );
        client
            .submit_to(
                swap_id,
                ds.x.row(submitted as usize % ds.len()).to_vec(),
            )
            .unwrap();
        submitted += 1;
        while let Some(c) = client.recv(Duration::from_millis(20)) {
            let r = c.expect("no errors across the remote hot swap");
            seen_gen2 |= r.generation == 2;
            responses.push(r);
        }
    }
    while (responses.len() as u64) < submitted {
        let r = client
            .recv(common::recv_deadline())
            .expect("lost in-flight response across the remote swap")
            .expect("no errors across the remote hot swap");
        responses.push(r);
    }
    let mut seen_ids = std::collections::HashSet::new();
    let mut gens = [0usize; 3];
    for r in &responses {
        assert!(seen_ids.insert(r.id), "duplicate completion {}", r.id);
        gens[r.generation as usize] += 1;
    }
    assert!(gens[1] > 0, "generation 1 never served");
    assert!(gens[2] > 0, "generation 2 never served");
    assert_eq!(router.metrics().dropped, 0);
    router.shutdown();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn killing_one_shard_fails_fast_for_its_tenants_only() {
    if !remote_enabled() {
        eprintln!("skipping: APPROXRBF_TEST_REMOTE != 1");
        return;
    }
    let (store, tenants) = mixed_registry("failfast");
    // Partition the tenant set by owning shard process; both shards
    // must own someone for this test to mean anything (true for this
    // fixed tenant set, asserted anyway).
    let owned_by = |shard: usize| -> Vec<&'static str> {
        tenants
            .iter()
            .map(|(id, _)| *id)
            .filter(|id| Router::place_for(id, 2) == shard)
            .collect()
    };
    let victims = owned_by(0);
    let survivors = owned_by(1);
    assert!(
        !victims.is_empty() && !survivors.is_empty(),
        "degenerate placement: {victims:?} / {survivors:?}"
    );

    let (mut shards, router) = spawn_plane(&store);
    let client = router.client();
    // Warm both shards up with one served request each.
    for id in [&victims[0], &survivors[0]] {
        let ds = &tenants.iter().find(|(t, _)| t == id).unwrap().1;
        client.submit_to(id, ds.x.row(0).to_vec()).unwrap();
        client
            .recv(common::recv_deadline())
            .expect("warmup response")
            .expect("warmup must serve");
    }

    // Kill shard process 0 (SIGKILL — no goodbye frame).
    shards[0].kill();

    // Every victim-tenant request must resolve to a typed error within
    // the deadline — whether it raced into the dying socket (failed by
    // the router's teardown) or arrived after detection (failed at
    // submit). Nothing may hang.
    let t0 = Instant::now();
    let mut victim_errors = 0usize;
    for round in 0..40 {
        for id in &victims {
            let ds = &tenants.iter().find(|(t, _)| t == id).unwrap().1;
            match client.submit_to(id, ds.x.row(round % ds.len()).to_vec())
            {
                Err(e) => {
                    assert!(
                        matches!(e.kind, PredictErrorKind::Exec { .. }),
                        "unexpected error kind: {e}"
                    );
                    victim_errors += 1;
                }
                Ok(_) => match client.recv(common::recv_deadline()) {
                    Some(Err(e)) => {
                        assert!(
                            matches!(
                                e.kind,
                                PredictErrorKind::Exec { .. }
                                    | PredictErrorKind::Shutdown
                            ),
                            "unexpected error kind: {e}"
                        );
                        victim_errors += 1;
                    }
                    Some(Ok(r)) => {
                        panic!("dead shard served request {}", r.id)
                    }
                    None => panic!("victim request hung (no completion)"),
                },
            }
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    assert_eq!(victim_errors, 40 * victims.len());
    assert!(
        t0.elapsed() < common::deadline(),
        "fail-fast path took {:?}",
        t0.elapsed()
    );

    // The surviving shard's tenants are untouched.
    let mut session = client.session();
    for id in &survivors {
        let ds = &tenants.iter().find(|(t, _)| t == id).unwrap().1;
        for r in 0..10 {
            session.submit_to(id, ds.x.row(r).to_vec()).unwrap();
        }
    }
    let completions = session.wait_all(common::deadline()).unwrap();
    assert_eq!(completions.len(), 10 * survivors.len());
    for c in completions {
        c.expect("surviving shard's tenants must keep serving");
    }
    router.shutdown();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn rollback_over_the_wire_matches_local_plane() {
    if !remote_enabled() {
        eprintln!("skipping: APPROXRBF_TEST_REMOTE != 1");
        return;
    }
    let store = Arc::new(ModelStore::open(temp_dir("rollback")).unwrap());
    let (m1, a1, ds) = trained_pair(707, 0.8);
    assert_eq!(store.publish("roll", &m1, &a1).unwrap(), 1);
    let traffic: Vec<(&'static str, Vec<f32>)> = (0..60)
        .map(|i| ("roll", ds.x.row(i % ds.len()).to_vec()))
        .collect();

    let (_shards, router) = spawn_plane(&store);
    // Serve the fixed traffic over the wire and pin the generation
    // every response came from.
    let serve_remote = |expect_gen: u64| -> Vec<Served> {
        let client = router.client();
        let mut session = client.session();
        for (id, z) in &traffic {
            session.submit_to(id, z.clone()).unwrap();
        }
        let rows: Vec<Served> = session
            .wait_all(common::long_deadline())
            .unwrap()
            .into_iter()
            .map(|c| {
                let r = c.expect("no failures over the wire");
                (
                    r.model.to_string(),
                    r.generation,
                    r.decision.to_bits(),
                    r.route,
                )
            })
            .collect();
        assert!(
            rows.iter().all(|(_, g, _, _)| *g == expect_gen),
            "expected every response from generation {expect_gen}"
        );
        rows
    };
    let bits = |rows: &[Served]| -> Vec<u32> {
        rows.iter().map(|(_, _, b, _)| *b).collect()
    };

    // Generation 1: remote must match a local plane on the same store.
    let remote1 = serve_remote(1);
    assert_eq!(remote1, run_in_process(&store, &traffic));

    // Generation 2: republish a different model, nudge the shard
    // processes over the wire, compare again.
    let (m2, a2, _) = trained_pair(808, 0.7);
    assert_eq!(store.publish("roll", &m2, &a2).unwrap(), 2);
    assert_eq!(router.refresh().unwrap(), 2, "both shards must ack");
    let remote2 = serve_remote(2);
    assert_eq!(remote2, run_in_process(&store, &traffic));
    assert_ne!(
        bits(&remote1),
        bits(&remote2),
        "distinct models must decide differently somewhere"
    );

    // Generation 3 = rollback: generation 1's payload republished as a
    // fresh generation. The remote plane must serve generation 1's
    // exact decision bits again — and still match a local plane.
    assert_eq!(store.rollback("roll").unwrap(), 3);
    assert_eq!(router.refresh().unwrap(), 2, "both shards must ack");
    let remote3 = serve_remote(3);
    assert_eq!(remote3, run_in_process(&store, &traffic));
    assert_eq!(
        bits(&remote3),
        bits(&remote1),
        "rollback must restore generation 1's decision bits on the wire"
    );
    router.shutdown();
    let _ = std::fs::remove_dir_all(store.root());
}
