//! `.arbf` format conformance against the committed golden corpus
//! (`rust/tests/data/*.arbf`, regenerated only by
//! `rust/tests/data/gen_fixtures.py`).
//!
//! The corpus pins format version 1, record kinds 1–6 and the header
//! flag bits at the **byte** level:
//!
//! * every fixture byte-decodes to known header fields and tensors;
//! * per-record CRCs recompute to the stored values;
//! * the Rust encoders reproduce every fixture **byte-for-byte**
//!   (`encode(decode(x)) == x`), so any accidental layout change —
//!   reordered fields, changed widths, different sparsity rule — fails
//!   loudly here before it silently orphans every published registry;
//! * deliberate mutations (magic, version, flags, payload bytes,
//!   truncation) are rejected with typed `Error::Corrupt`, while flips
//!   confined to ignored reserved bytes still decode identically.
//!
//! Every fixture value is dyadic, so f32/f16/int8 round trips in the
//! corpus are exact and the assertions below can use `==` on floats.
//!
//! The corpus also pins format version 2 (the zero-copy layout): four
//! `v2_*` twins of the bundle fixtures hold the same toy values under
//! the 64-byte-aligned framing, and the tests below assert byte
//! stability per format, v1↔v2 re-encode round trips, bit-identical
//! decisions between heap and mapped decodes, and loud rejection of
//! pad-word / filler tampering that v1's CRCs alone would not catch.

use std::sync::Arc;

use approxrbf::coordinator::{RoutePolicy, TenantPolicy};
use approxrbf::linalg::Mat;
use approxrbf::registry::binfmt::{
    self, FLAG_HAS_POLICY, FLAG_QUANT_F16, FLAG_QUANT_INT8, FLAG_RFF,
};
use approxrbf::registry::{FormatVersion, MapFile, PayloadKind, TenantModels};
use approxrbf::approx::{ApproxModel, RffModel};
use approxrbf::svm::{Kernel, SvmModel};
use approxrbf::util::crc32::crc32;
use approxrbf::Error;
use std::time::Duration;

fn fixture(name: &str) -> Vec<u8> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data")
        .join(name);
    std::fs::read(&path)
        .unwrap_or_else(|e| panic!("golden fixture {name}: {e}"))
}

/// The f32/f16 toy pair — mirrors gen_fixtures.py exactly.
fn toy_svm() -> SvmModel {
    SvmModel::new(
        Kernel::Rbf { gamma: 0.25 },
        Mat::from_vec(3, 3, vec![1., 0., 2., 0., 2., 0., -1., 1., 0.5])
            .unwrap(),
        vec![0.5, -1.0, 0.75],
        0.125,
    )
    .unwrap()
}

fn toy_approx() -> ApproxModel {
    ApproxModel {
        gamma: 0.125,
        b: -0.25,
        c: 0.5,
        v: vec![1.0, -2.0, 0.25],
        m: Mat::from_vec(
            3,
            3,
            vec![0.5, 0.25, -1.0, 0.25, -0.75, 2.0, -1.0, 2.0, 0.125],
        )
        .unwrap(),
        max_sv_norm_sq: 4.0,
    }
}

/// The int8 toy pair: every row max is 127·2⁻ᵏ, so quantization is
/// exact and these f32 models quantize to the fixture's q/scales.
fn toy_svm_int8() -> SvmModel {
    SvmModel::new(
        Kernel::Rbf { gamma: 0.25 },
        Mat::from_vec(
            3,
            3,
            vec![
                0.9921875, 0.0, 0.5, //
                0.0, 0.9921875, 0.0, //
                -0.49609375, 0.25, 0.0,
            ],
        )
        .unwrap(),
        vec![0.9921875, -0.5, 0.25],
        0.125,
    )
    .unwrap()
}

fn toy_approx_int8() -> ApproxModel {
    ApproxModel {
        gamma: 0.125,
        b: -0.25,
        c: 0.5,
        v: vec![0.9921875, -0.5, 0.25],
        m: Mat::from_vec(
            3,
            3,
            vec![
                0.9921875, 0.25, -0.5, //
                0.25, -0.9921875, 0.75, //
                -0.5, 0.75, 0.49609375,
            ],
        )
        .unwrap(),
        max_sv_norm_sq: 4.0,
    }
}

/// The kind-6 record: only the dyadic stored half lives in the file —
/// the projection and phases regenerate from seed 42 at decode.
fn toy_rff() -> RffModel {
    RffModel::from_parts(3, 42, 0.125, 0.125, 0.25, vec![0.5, -1.0, 0.25, 2.0])
        .unwrap()
}

fn toy_policy() -> TenantPolicy {
    // quant_drift_tol stays None: the golden fixtures pin the 19-byte
    // v1 policy body, which is exactly what an unset tolerance writes.
    TenantPolicy {
        route: Some(RoutePolicy::AlwaysExact),
        max_batch: Some(32),
        max_wait: Some(Duration::from_micros(750)),
        max_resident_hint: 5,
        quant_drift_tol: None,
    }
}

fn assert_crcs_recompute(bytes: &[u8]) {
    for (i, frame) in binfmt::record_frames(bytes).unwrap().iter().enumerate()
    {
        let start = frame.payload_offset;
        let end = start + frame.payload_len as usize;
        assert_eq!(
            crc32(&bytes[start..end]),
            frame.crc32,
            "record {i}: stored CRC does not recompute"
        );
    }
}

// ---------------------------------------------------------------------
// per-fixture conformance
// ---------------------------------------------------------------------

#[test]
fn golden_v1_svm_standalone() {
    let bytes = fixture("v1_svm.arbf");
    let hdr = binfmt::peek_header(&bytes).unwrap();
    assert_eq!(
        (hdr.version, hdr.n_records, hdr.generation),
        (1, 1, 0)
    );
    assert_eq!((hdr.dim, hdr.n_sv, hdr.flags), (3, 3, 0));
    assert_eq!(hdr.payload(), PayloadKind::F32);
    assert_crcs_recompute(&bytes);
    let m = binfmt::decode_svm(&bytes).unwrap();
    let want = toy_svm();
    assert_eq!(m.kernel, want.kernel);
    assert_eq!(m.b, want.b);
    assert_eq!(m.coef, want.coef);
    assert_eq!(m.sv.max_abs_diff(&want.sv), 0.0);
    // Byte stability: the encoder reproduces the committed fixture.
    assert_eq!(binfmt::encode_svm(&want).unwrap(), bytes);
}

#[test]
fn golden_v1_approx_standalone() {
    let bytes = fixture("v1_approx.arbf");
    let hdr = binfmt::peek_header(&bytes).unwrap();
    assert_eq!((hdr.n_records, hdr.generation, hdr.dim, hdr.n_sv), (1, 0, 3, 0));
    assert_crcs_recompute(&bytes);
    let a = binfmt::decode_approx(&bytes).unwrap();
    let want = toy_approx();
    assert_eq!(a.gamma, want.gamma);
    assert_eq!(a.b, want.b);
    assert_eq!(a.c, want.c);
    assert_eq!(a.max_sv_norm_sq, want.max_sv_norm_sq);
    assert_eq!(a.v, want.v);
    assert_eq!(a.m.max_abs_diff(&want.m), 0.0);
    assert_eq!(binfmt::encode_approx(&want).unwrap(), bytes);
}

#[test]
fn golden_v1_bundle_with_policy() {
    let bytes = fixture("v1_bundle_policy.arbf");
    let hdr = binfmt::peek_header(&bytes).unwrap();
    assert_eq!((hdr.n_records, hdr.generation), (3, 7));
    assert_eq!(hdr.flags, FLAG_HAS_POLICY);
    assert!(hdr.has_policy());
    assert_eq!(hdr.payload(), PayloadKind::F32);
    assert_crcs_recompute(&bytes);
    let b = binfmt::decode_bundle_full(&bytes).unwrap();
    assert_eq!(b.generation, 7);
    assert_eq!(b.policy, Some(toy_policy()));
    let e = b.exact_dequant();
    let a = b.approx_dequant();
    assert_eq!(e.coef, toy_svm().coef);
    assert_eq!(a.v, toy_approx().v);
    assert_eq!(
        binfmt::encode_bundle_with(
            7,
            &toy_svm(),
            &toy_approx(),
            Some(&toy_policy())
        )
        .unwrap(),
        bytes
    );
    // The native re-encode of the decoded bundle is identical too.
    assert_eq!(
        binfmt::encode_bundle_native(7, &b.models, b.policy.as_ref())
            .unwrap(),
        bytes
    );
}

#[test]
fn golden_v1_bundle_f16() {
    let bytes = fixture("v1_bundle_f16.arbf");
    let hdr = binfmt::peek_header(&bytes).unwrap();
    assert_eq!((hdr.n_records, hdr.generation), (2, 3));
    assert_eq!(hdr.flags, FLAG_QUANT_F16);
    assert_eq!(hdr.payload(), PayloadKind::F16);
    assert_crcs_recompute(&bytes);
    let frames = binfmt::record_frames(&bytes).unwrap();
    assert!(frames.iter().all(|f| f.kind == 4));
    let b = binfmt::decode_bundle_full(&bytes).unwrap();
    assert_eq!(b.payload(), PayloadKind::F16);
    // Every fixture value is f16-exact, so dequantization is lossless.
    let e = b.exact_dequant();
    let a = b.approx_dequant();
    assert_eq!(e.coef, toy_svm().coef);
    assert_eq!(e.sv.max_abs_diff(&toy_svm().sv), 0.0);
    assert_eq!(e.b, 0.125);
    assert_eq!(a.v, toy_approx().v);
    assert_eq!(a.m.max_abs_diff(&toy_approx().m), 0.0);
    // Byte stability via BOTH paths: re-encoding the decoded native
    // storage, and re-quantizing the f32 twins from scratch.
    assert_eq!(
        binfmt::encode_bundle_native(3, &b.models, None).unwrap(),
        bytes
    );
    assert_eq!(
        binfmt::encode_bundle_quantized(
            3,
            &toy_svm(),
            &toy_approx(),
            None,
            PayloadKind::F16
        )
        .unwrap(),
        bytes
    );
}

#[test]
fn golden_v1_bundle_int8_with_policy() {
    let bytes = fixture("v1_bundle_int8_policy.arbf");
    let hdr = binfmt::peek_header(&bytes).unwrap();
    assert_eq!((hdr.n_records, hdr.generation), (3, 9));
    assert_eq!(hdr.flags, FLAG_QUANT_INT8 | FLAG_HAS_POLICY);
    assert_eq!(hdr.payload(), PayloadKind::Int8);
    assert_crcs_recompute(&bytes);
    let frames = binfmt::record_frames(&bytes).unwrap();
    assert_eq!(
        frames.iter().map(|f| f.kind).collect::<Vec<_>>(),
        vec![5, 5, 3]
    );
    let b = binfmt::decode_bundle_full(&bytes).unwrap();
    assert_eq!(b.payload(), PayloadKind::Int8);
    assert_eq!(b.policy, Some(toy_policy()));
    // Dyadic scales (2⁻⁷ / 2⁻⁸) make dequantization exact.
    let e = b.exact_dequant();
    let a = b.approx_dequant();
    assert_eq!(e.coef, toy_svm_int8().coef);
    assert_eq!(e.sv.max_abs_diff(&toy_svm_int8().sv), 0.0);
    assert_eq!(a.v, toy_approx_int8().v);
    assert_eq!(a.m.max_abs_diff(&toy_approx_int8().m), 0.0);
    match &b.models {
        TenantModels::Quantized { approx, .. } => {
            // Spot-check the stored quantized state itself.
            assert_eq!(approx.v.get(0), 0.9921875);
            assert_eq!(approx.m.get(2, 2), 0.49609375);
            assert_eq!(approx.m.get(0, 2), approx.m.get(2, 0));
        }
        TenantModels::F32 { .. } => panic!("int8 fixture decoded as f32"),
    }
    assert_eq!(
        binfmt::encode_bundle_native(9, &b.models, b.policy.as_ref())
            .unwrap(),
        bytes
    );
    // Quantizing the exact-dyadic f32 twins reproduces the same bytes:
    // scale = max|row|/127 = 2⁻ᵏ exactly, q = value/scale exactly.
    assert_eq!(
        binfmt::encode_bundle_quantized(
            9,
            &toy_svm_int8(),
            &toy_approx_int8(),
            Some(&toy_policy()),
            PayloadKind::Int8
        )
        .unwrap(),
        bytes
    );
}

#[test]
fn golden_v1_bundle_rff() {
    let bytes = fixture("v1_bundle_rff.arbf");
    let hdr = binfmt::peek_header(&bytes).unwrap();
    assert_eq!((hdr.n_records, hdr.generation), (3, 11));
    assert_eq!(hdr.flags, FLAG_RFF);
    assert!(hdr.has_rff());
    // Substrate and precision are orthogonal: an rff bundle is f32.
    assert_eq!(hdr.payload(), PayloadKind::F32);
    assert_crcs_recompute(&bytes);
    let frames = binfmt::record_frames(&bytes).unwrap();
    assert_eq!(
        frames.iter().map(|f| f.kind).collect::<Vec<_>>(),
        vec![1, 2, 6]
    );
    // The kind-6 payload is the fixed 28-byte head plus D×f32 weights.
    assert_eq!(frames[2].payload_len, 28 + 4 * 4);
    let b = binfmt::decode_bundle_full(&bytes).unwrap();
    assert_eq!(b.generation, 11);
    assert_eq!(b.payload(), PayloadKind::F32);
    let e = b.exact_dequant();
    let a = b.approx_dequant();
    assert_eq!(e.coef, toy_svm().coef);
    assert_eq!(a.v, toy_approx().v);
    let r = b.models.rff().expect("rff fixture decoded without kind-6");
    assert_eq!((r.dim(), r.n_features()), (3, 4));
    assert_eq!(r.seed, 42);
    assert_eq!((r.gamma, r.bias, r.err_est), (0.125, 0.125, 0.25));
    assert_eq!(r.w, vec![0.5, -1.0, 0.25, 2.0]);
    // Byte stability via BOTH paths: re-encoding the decoded native
    // storage, and rebuilding the record from its stored parts.
    assert_eq!(
        binfmt::encode_bundle_native(11, &b.models, None).unwrap(),
        bytes
    );
    assert_eq!(
        binfmt::encode_bundle_rff(11, &toy_svm(), &toy_approx(), &toy_rff(), None)
            .unwrap(),
        bytes
    );
}

#[test]
fn rff_feature_map_regenerates_deterministically() {
    // The file never ships W or φ — serving correctness rests on the
    // seeded regeneration being bit-stable across decodes and across
    // an independent from_parts reconstruction.
    let bytes = fixture("v1_bundle_rff.arbf");
    let once = binfmt::decode_bundle_full(&bytes).unwrap();
    let twice = binfmt::decode_bundle_full(&bytes).unwrap();
    let local = toy_rff();
    for z in [
        [0.0f32, 0.0, 0.0],
        [1.0, -0.5, 0.25],
        [-2.0, 0.125, 3.0],
        [0.5, 0.5, -0.5],
    ] {
        let d0 = once.models.rff().unwrap().decision_one(&z).0;
        let d1 = twice.models.rff().unwrap().decision_one(&z).0;
        let d2 = local.decision_one(&z).0;
        assert_eq!(d0.to_bits(), d1.to_bits(), "decode/decode drift at {z:?}");
        assert_eq!(d0.to_bits(), d2.to_bits(), "decode/from_parts drift at {z:?}");
    }
}

// ---------------------------------------------------------------------
// deliberate mutations must fail loudly (and reserved bytes must not)
// ---------------------------------------------------------------------

#[test]
fn every_fixture_rejects_deliberate_mutations() {
    for name in [
        "v1_svm.arbf",
        "v1_approx.arbf",
        "v1_bundle_policy.arbf",
        "v1_bundle_f16.arbf",
        "v1_bundle_int8_policy.arbf",
        "v1_bundle_rff.arbf",
    ] {
        let bytes = fixture(name);
        let check = |mutated: Vec<u8>, what: &str| {
            assert!(
                matches!(binfmt::decode(&mutated), Err(Error::Corrupt(_))),
                "{name}: {what} mutation must be Corrupt"
            );
        };
        // Magic, version, record-count, flags word.
        let mut m = bytes.clone();
        m[0] ^= 0x01;
        check(m, "magic");
        let mut m = bytes.clone();
        m[4] = 99;
        check(m, "version");
        let mut m = bytes.clone();
        m[6] = 0xff;
        m[7] = 0xff;
        check(m, "record count");
        // A flipped payload byte breaks the CRC.
        let mut m = bytes.clone();
        let last = m.len() - 1;
        m[last] ^= 0x80;
        check(m, "payload tail");
        let frames = binfmt::record_frames(&bytes).unwrap();
        let mid = frames[0].payload_offset + 2;
        let mut m = bytes.clone();
        m[mid] ^= 0x04;
        check(m, "payload head");
        // Truncation at every boundary-ish cut.
        for cut in [0, 5, 31, 33, bytes.len() - 1] {
            check(bytes[..cut].to_vec(), "truncation");
        }
        // Trailing junk.
        let mut m = bytes.clone();
        m.push(0);
        check(m, "trailing junk");
        // …but a flip confined to a record header's reserved u16 (not
        // CRC-covered, documented ignored) still decodes identically.
        let reserved_off = frames[0].payload_offset - 14; // kind(2)+res(2)+crc(4)+len(8)
        let mut m = bytes.clone();
        m[reserved_off] = 0xaa;
        let a = binfmt::decode(&bytes).unwrap();
        let b = binfmt::decode(&m).unwrap();
        assert_eq!(a.1.len(), b.1.len(), "{name}: reserved flip changed decode");
    }
}

#[test]
fn quant_flag_and_record_mismatch_is_corrupt() {
    // Clearing the f16 flag leaves kind-4 records behind an f32 header
    // claim — decode_bundle_full must refuse the inconsistency.
    let mut bytes = fixture("v1_bundle_f16.arbf");
    bytes[24] &= !(FLAG_QUANT_F16 as u8);
    assert!(matches!(
        binfmt::decode_bundle_full(&bytes),
        Err(Error::Corrupt(m)) if m.contains("advertises")
    ));
}

#[test]
fn rff_flag_and_record_mismatch_is_corrupt() {
    // Clearing FLAG_RFF leaves a kind-6 record the header denies.
    let mut bytes = fixture("v1_bundle_rff.arbf");
    bytes[24] &= !(FLAG_RFF as u8);
    assert!(matches!(
        binfmt::decode_bundle_full(&bytes),
        Err(Error::Corrupt(m)) if m.contains("advertises")
    ));
    // Setting FLAG_RFF on a plain bundle promises a kind-6 that never
    // arrives.
    let mut bytes = fixture("v1_bundle_policy.arbf");
    bytes[24] |= FLAG_RFF as u8;
    assert!(matches!(
        binfmt::decode_bundle_full(&bytes),
        Err(Error::Corrupt(m)) if m.contains("advertises")
    ));
    // rff + quantized flags are mutually exclusive — rejected at peek,
    // before any payload is trusted.
    let mut bytes = fixture("v1_bundle_rff.arbf");
    bytes[24] |= FLAG_QUANT_F16 as u8;
    assert!(matches!(
        binfmt::peek_header(&bytes),
        Err(Error::Corrupt(m)) if m.contains("rff and quantized")
    ));
}

#[test]
fn quantized_fixture_serves_decisions_equal_to_dequantized_eval() {
    // End-of-pipe sanity on the corpus: the native int8 evaluation of
    // the fixture matches its (exactly) dequantized twin within the
    // reported bound. The fixture's dyadic weights dequantize exactly,
    // so the only drift left is the i16 *query* quantization of the
    // integer kernels (scale max|z|/32767 is never dyadic) — far
    // inside the advertised decision bound, and bit-identical across
    // every dispatch arm.
    let b = binfmt::decode_bundle_full(&fixture("v1_bundle_int8_policy.arbf"))
        .unwrap();
    let z = [0.25f32, -0.5, 0.125];
    let zn = approxrbf::linalg::vecops::norm_sq(&z);
    let native = b.models.approx_decision_one(&z);
    let (deq, _) = b.approx_dequant().decision_one(&z);
    let bound = b.models.quant_error().unwrap().decision_error(zn);
    assert!((native - deq).abs() <= bound, "{native} vs {deq} (> {bound})");
    // Still essentially equal: the query term is ~2⁻¹⁵ relative.
    assert!((native - deq).abs() < 1e-3, "{native} vs {deq}");
    if let approxrbf::registry::TenantModels::Quantized { approx, .. } =
        &b.models
    {
        for arm in approxrbf::linalg::quantblas::available_arms() {
            let via = approx.decision_one_with(arm, &z).0;
            assert_eq!(via.to_bits(), native.to_bits(), "{arm}");
        }
    } else {
        panic!("int8 fixture decoded as f32");
    }
}

// ---------------------------------------------------------------------
// format v2: zero-copy framing over the same record kinds
// ---------------------------------------------------------------------

/// Every v2 payload must sit on a 64-byte file offset, reached by the
/// pad count committed in the record header.
fn assert_v2_framing(bytes: &[u8]) {
    for (i, f) in binfmt::record_frames(bytes).unwrap().iter().enumerate() {
        assert_eq!(f.payload_offset % 64, 0, "record {i}: payload misaligned");
        assert!((f.pad as usize) < 64, "record {i}: overlong pad {}", f.pad);
    }
}

#[test]
fn golden_v2_bundle_with_policy() {
    let bytes = fixture("v2_bundle_policy.arbf");
    let hdr = binfmt::peek_header(&bytes).unwrap();
    assert_eq!((hdr.version, hdr.n_records, hdr.generation), (2, 3, 7));
    assert_eq!(hdr.format(), FormatVersion::V2);
    assert_eq!(hdr.flags, FLAG_HAS_POLICY);
    assert_eq!(hdr.payload(), PayloadKind::F32);
    assert_crcs_recompute(&bytes);
    assert_v2_framing(&bytes);
    let b = binfmt::decode_bundle_full(&bytes).unwrap();
    assert_eq!(b.format, FormatVersion::V2);
    assert_eq!(b.policy, Some(toy_policy()));
    assert_eq!(b.exact_dequant().coef, toy_svm().coef);
    assert_eq!(b.approx_dequant().v, toy_approx().v);
    assert_eq!(
        binfmt::encode_bundle_native_at(
            7,
            &b.models,
            b.policy.as_ref(),
            FormatVersion::V2
        )
        .unwrap(),
        bytes
    );
    // f32 payloads serve from the heap in either format: a mapped
    // decode of this bundle borrows nothing.
    let map = Arc::new(MapFile::from_bytes(bytes));
    let m = binfmt::decode_bundle_mapped(&map).unwrap();
    assert_eq!(m.models.mapped_bytes(), 0);
}

#[test]
fn golden_v2_bundle_f16() {
    let bytes = fixture("v2_bundle_f16.arbf");
    let hdr = binfmt::peek_header(&bytes).unwrap();
    assert_eq!((hdr.n_records, hdr.generation), (2, 3));
    assert_eq!(hdr.flags, FLAG_QUANT_F16);
    assert_eq!(hdr.payload(), PayloadKind::F16);
    assert_crcs_recompute(&bytes);
    assert_v2_framing(&bytes);
    assert!(binfmt::record_frames(&bytes)
        .unwrap()
        .iter()
        .all(|f| f.kind == 4));
    let b = binfmt::decode_bundle_full(&bytes).unwrap();
    assert_eq!(b.payload(), PayloadKind::F16);
    // Same dyadic toy values as the v1 twin — lossless dequantization.
    let e = b.exact_dequant();
    let a = b.approx_dequant();
    assert_eq!(e.coef, toy_svm().coef);
    assert_eq!(e.sv.max_abs_diff(&toy_svm().sv), 0.0);
    assert_eq!(a.v, toy_approx().v);
    assert_eq!(a.m.max_abs_diff(&toy_approx().m), 0.0);
    // Byte stability via BOTH paths, at the v2 container.
    assert_eq!(
        binfmt::encode_bundle_native_at(3, &b.models, None, FormatVersion::V2)
            .unwrap(),
        bytes
    );
    assert_eq!(
        binfmt::encode_bundle_quantized_at(
            3,
            &toy_svm(),
            &toy_approx(),
            None,
            PayloadKind::F16,
            FormatVersion::V2
        )
        .unwrap(),
        bytes
    );
}

#[test]
fn golden_v2_bundle_int8_with_policy() {
    let bytes = fixture("v2_bundle_int8_policy.arbf");
    let hdr = binfmt::peek_header(&bytes).unwrap();
    assert_eq!((hdr.n_records, hdr.generation), (3, 9));
    assert_eq!(hdr.flags, FLAG_QUANT_INT8 | FLAG_HAS_POLICY);
    assert_eq!(hdr.payload(), PayloadKind::Int8);
    assert_crcs_recompute(&bytes);
    assert_v2_framing(&bytes);
    let frames = binfmt::record_frames(&bytes).unwrap();
    assert_eq!(
        frames.iter().map(|f| f.kind).collect::<Vec<_>>(),
        vec![5, 5, 3]
    );
    let b = binfmt::decode_bundle_full(&bytes).unwrap();
    assert_eq!(b.policy, Some(toy_policy()));
    let e = b.exact_dequant();
    let a = b.approx_dequant();
    assert_eq!(e.coef, toy_svm_int8().coef);
    assert_eq!(e.sv.max_abs_diff(&toy_svm_int8().sv), 0.0);
    assert_eq!(a.m.max_abs_diff(&toy_approx_int8().m), 0.0);
    assert_eq!(
        binfmt::encode_bundle_native_at(
            9,
            &b.models,
            b.policy.as_ref(),
            FormatVersion::V2
        )
        .unwrap(),
        bytes
    );
    assert_eq!(
        binfmt::encode_bundle_quantized_at(
            9,
            &toy_svm_int8(),
            &toy_approx_int8(),
            Some(&toy_policy()),
            PayloadKind::Int8,
            FormatVersion::V2
        )
        .unwrap(),
        bytes
    );
}

#[test]
fn golden_v2_bundle_rff() {
    let bytes = fixture("v2_bundle_rff.arbf");
    let hdr = binfmt::peek_header(&bytes).unwrap();
    assert_eq!((hdr.n_records, hdr.generation), (3, 11));
    assert_eq!(hdr.flags, FLAG_RFF);
    assert_crcs_recompute(&bytes);
    assert_v2_framing(&bytes);
    let frames = binfmt::record_frames(&bytes).unwrap();
    assert_eq!(
        frames.iter().map(|f| f.kind).collect::<Vec<_>>(),
        vec![1, 2, 6]
    );
    // v2 pads the 28-byte prefix out to one alignment unit, then D×f32.
    assert_eq!(frames[2].payload_len, 64 + 4 * 4);
    // The 28-byte prefix is format-invariant: peek serves v2 unchanged.
    let s = binfmt::peek_rff_summary(&bytes).unwrap().expect("kind-6 peek");
    assert_eq!((s.n_features, s.seed, s.gamma, s.err_est), (4, 42, 0.125, 0.25));
    let b = binfmt::decode_bundle_full(&bytes).unwrap();
    let r = b.models.rff().expect("rff fixture decoded without kind-6");
    assert_eq!((r.dim(), r.n_features()), (3, 4));
    assert_eq!(r.w, vec![0.5, -1.0, 0.25, 2.0]);
    assert_eq!(
        binfmt::encode_bundle_native_at(11, &b.models, None, FormatVersion::V2)
            .unwrap(),
        bytes
    );
    assert_eq!(
        binfmt::encode_bundle_rff_at(
            11,
            &toy_svm(),
            &toy_approx(),
            &toy_rff(),
            None,
            FormatVersion::V2
        )
        .unwrap(),
        bytes
    );
}

#[test]
fn v1_to_v2_reencode_round_trips_byte_identically() {
    // migrate()'s codec core: decode v1, re-encode at v2 — which must
    // reproduce the committed v2 twin exactly — decode that, re-encode
    // at v1, and land back on the original bytes.
    for name in [
        "v1_bundle_policy.arbf",
        "v1_bundle_f16.arbf",
        "v1_bundle_int8_policy.arbf",
        "v1_bundle_rff.arbf",
    ] {
        let bytes = fixture(name);
        let b = binfmt::decode_bundle_full(&bytes).unwrap();
        let v2 = binfmt::encode_bundle_native_at(
            b.generation,
            &b.models,
            b.policy.as_ref(),
            FormatVersion::V2,
        )
        .unwrap();
        assert_eq!(
            v2,
            fixture(&name.replace("v1_", "v2_")),
            "{name}: v2 re-encode does not match the committed twin"
        );
        let b2 = binfmt::decode_bundle_full(&v2).unwrap();
        let back = binfmt::encode_bundle_native_at(
            b2.generation,
            &b2.models,
            b2.policy.as_ref(),
            FormatVersion::V1,
        )
        .unwrap();
        assert_eq!(back, bytes, "{name}: v1 -> v2 -> v1 drifted");
    }
}

#[test]
fn v2_fixtures_serve_mapped_decisions_bit_identical_to_v1_heap() {
    // The serving contract the whole zero-copy layer rests on: a v2
    // bundle decoded over its mapped backing produces decisions
    // bit-identical to the v1 heap decode of the same model.
    for (v1, v2) in [
        ("v1_bundle_policy.arbf", "v2_bundle_policy.arbf"),
        ("v1_bundle_f16.arbf", "v2_bundle_f16.arbf"),
        ("v1_bundle_int8_policy.arbf", "v2_bundle_int8_policy.arbf"),
        ("v1_bundle_rff.arbf", "v2_bundle_rff.arbf"),
    ] {
        let heap = binfmt::decode_bundle_full(&fixture(v1)).unwrap();
        let map = Arc::new(MapFile::from_bytes(fixture(v2)));
        let mapped = binfmt::decode_bundle_mapped(&map).unwrap();
        assert_eq!(heap.payload(), mapped.payload(), "{v2}: payload kind");
        let borrows = !matches!(mapped.models, TenantModels::F32 { .. });
        if cfg!(target_endian = "little") && borrows {
            assert!(
                mapped.models.mapped_bytes() > 0,
                "{v2}: expected mapped tensor views"
            );
        }
        for z in [
            [0.25f32, -0.5, 0.125],
            [1.0, 0.0, -1.0],
            [-0.125, 2.0, 0.5],
            [0.0, 0.0, 0.0],
        ] {
            let want = heap.models.approx_decision_one(&z);
            let got = mapped.models.approx_decision_one(&z);
            assert_eq!(
                want.to_bits(),
                got.to_bits(),
                "{v2}: mapped decision drift at {z:?}"
            );
        }
    }
}

#[test]
fn v2_fixtures_reject_framing_mutations() {
    for name in [
        "v2_bundle_policy.arbf",
        "v2_bundle_f16.arbf",
        "v2_bundle_int8_policy.arbf",
        "v2_bundle_rff.arbf",
    ] {
        let bytes = fixture(name);
        let frames = binfmt::record_frames(&bytes).unwrap();
        // The first record always pads (header ends at offset 48).
        let f = &frames[0];
        assert!(f.pad > 0, "{name}: expected a padded first record");
        let hdr_start = f.payload_offset - f.pad as usize - 16;
        // In v2 the pad word is load-bearing: a flip that was "ignored
        // reserved bytes" under v1 now misplaces the payload.
        let mut m = bytes.clone();
        m[hdr_start + 2] = m[hdr_start + 2].wrapping_add(1);
        assert!(
            matches!(binfmt::decode(&m), Err(Error::Corrupt(msg))
                if msg.contains("boundary")),
            "{name}: bad pad word must miss the boundary"
        );
        // Filler tampering: the pad bytes precede the payload and are
        // not CRC-covered — only the explicit zero check refuses them.
        let mut m = bytes.clone();
        m[f.payload_offset - 1] = 0xAA;
        assert!(
            matches!(binfmt::decode(&m), Err(Error::Corrupt(msg))
                if msg.contains("padding")),
            "{name}: nonzero filler must be refused"
        );
        // Truncation inside the pad region stays typed.
        assert!(
            matches!(
                binfmt::decode(&bytes[..f.payload_offset - 1]),
                Err(Error::Corrupt(_))
            ),
            "{name}: truncation inside padding"
        );
        // And the CRC discipline is unchanged from v1.
        let mut m = bytes.clone();
        m[f.payload_offset] ^= 0x01;
        assert!(
            matches!(binfmt::decode(&m), Err(Error::Corrupt(_))),
            "{name}: payload flip must break the CRC"
        );
    }
}

#[test]
fn v2_intra_payload_padding_tamper_is_refused_even_with_valid_crc() {
    // The dense kind-4 payload carries CRC-covered zero filler between
    // tensor segments. Recomputing the CRC over a tampered filler byte
    // defeats the CRC check on purpose — the decoder's explicit zero
    // check must still refuse the payload.
    let bytes = fixture("v2_bundle_f16.arbf");
    let frames = binfmt::record_frames(&bytes).unwrap();
    let f = &frames[0];
    // Record 0: a 22-byte scalar prefix zero-padded to 64 before the
    // coefficient block, so payload byte 30 is intra-payload filler.
    let mut m = bytes.clone();
    m[f.payload_offset + 30] = 0xAA;
    let start = f.payload_offset;
    let end = start + f.payload_len as usize;
    let crc = crc32(&m[start..end]).to_le_bytes();
    let hdr_start = f.payload_offset - f.pad as usize - 16;
    m[hdr_start + 4..hdr_start + 8].copy_from_slice(&crc);
    assert!(matches!(
        binfmt::decode_bundle_full(&m),
        Err(Error::Corrupt(msg)) if msg.contains("alignment padding")
    ));
}
