//! Runtime parity: the PJRT-executed artifacts must agree with the
//! native Rust evaluators to f32 tolerance — the cross-language
//! correctness contract of the three-layer stack (L1/L2 pytest checks
//! Pallas vs jnp; this checks compiled-HLO-via-Rust vs native Rust).
//!
//! Skips (with a message) when `artifacts/` is absent.

#![cfg(feature = "pjrt")]

use std::path::Path;

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::data::SynthProfile;
use approxrbf::linalg::MathBackend;
use approxrbf::runtime::Engine;
use approxrbf::svm::predict::ExactPredictor;
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::Kernel;

fn engine() -> Option<Engine> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(dir).expect("engine load"))
}

fn tolerance(scale: f32) -> f32 {
    2e-3 * (1.0 + scale.abs())
}

#[test]
fn xla_approx_predict_matches_native() {
    let Some(engine) = engine() else { return };
    let (train, test) = SynthProfile::ControlLike.generate(123, 500, 300);
    let gamma = gamma_max_for_data(&train) * 0.8;
    let (model, _) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
    let prep = engine.prepare_approx(&am).unwrap();
    let (dec_xla, zn_xla) = engine.approx_predict(&prep, &test.x).unwrap();
    let (dec_nat, zn_nat) =
        am.decision_batch(&test.x, MathBackend::Blocked).unwrap();
    assert_eq!(dec_xla.len(), test.len());
    for r in 0..test.len() {
        assert!(
            (dec_xla[r] - dec_nat[r]).abs() < tolerance(dec_nat[r]),
            "row {r}: xla {} vs native {}",
            dec_xla[r],
            dec_nat[r]
        );
        assert!((zn_xla[r] - zn_nat[r]).abs() < tolerance(zn_nat[r]));
    }
}

#[test]
fn xla_exact_predict_matches_native() {
    let Some(engine) = engine() else { return };
    let (train, test) = SynthProfile::ControlLike.generate(124, 400, 250);
    let gamma = gamma_max_for_data(&train) * 0.9;
    let (model, _) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let prep = engine.prepare_exact(&model).unwrap();
    let dec_xla = engine.exact_predict(&prep, &test.x).unwrap();
    let dec_nat = ExactPredictor::new(&model, MathBackend::Blocked)
        .unwrap()
        .decision_batch(&test.x)
        .unwrap();
    for r in 0..test.len() {
        assert!(
            (dec_xla[r] - dec_nat[r]).abs() < tolerance(dec_nat[r]),
            "row {r}: xla {} vs native {}",
            dec_xla[r],
            dec_nat[r]
        );
    }
}

#[test]
fn xla_build_matches_native() {
    let Some(engine) = engine() else { return };
    let (train, _) = SynthProfile::ControlLike.generate(125, 400, 10);
    let gamma = gamma_max_for_data(&train) * 0.8;
    let (model, _) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let am_xla = engine.build_approx(&model).unwrap();
    let am_nat = build_approx_model(&model, MathBackend::Blocked).unwrap();
    assert!((am_xla.c - am_nat.c).abs() < tolerance(am_nat.c));
    for (a, b) in am_xla.v.iter().zip(&am_nat.v) {
        assert!((a - b).abs() < tolerance(*b));
    }
    let scale = am_nat.m.fro_norm() as f32;
    assert!(
        am_xla.m.max_abs_diff(&am_nat.m) < tolerance(scale),
        "M diff {}",
        am_xla.m.max_abs_diff(&am_nat.m)
    );
    // And the two approx models predict identically on fresh data.
    let (_, test) = SynthProfile::ControlLike.generate(126, 10, 100);
    let (dx, _) = am_xla.decision_batch(&test.x, MathBackend::Blocked).unwrap();
    let (dn, _) = am_nat.decision_batch(&test.x, MathBackend::Blocked).unwrap();
    for r in 0..test.len() {
        assert!((dx[r] - dn[r]).abs() < tolerance(dn[r]));
    }
}

#[test]
fn pallas_artifacts_match_jnp_artifacts() {
    // The interpret-mode Pallas lowering and the jnp lowering of the
    // same L2 function must agree when executed through PJRT.
    let Some(_engine) = engine() else { return };
    let dir = Path::new("artifacts");
    let (train, test) = SynthProfile::ControlLike.generate(127, 300, 128);
    let gamma = gamma_max_for_data(&train) * 0.8;
    let (model, _) =
        train_csvc(&train, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let am = build_approx_model(&model, MathBackend::Blocked).unwrap();

    // jnp engine (default) vs pallas engine (env-independent: construct
    // by flipping the preference field).
    let eng_jnp = Engine::load(dir).unwrap();
    let mut eng_pal = Engine::load(dir).unwrap();
    eng_pal.impl_kind = approxrbf::runtime::ImplKind::Pallas;
    if eng_pal
        .manifest()
        .select(
            approxrbf::runtime::ArtifactKind::Approx,
            approxrbf::runtime::ImplKind::Pallas,
            am.dim(),
            0,
        )
        .is_none()
    {
        eprintln!("skipping: no pallas artifacts for d={}", am.dim());
        return;
    }
    let prep_j = eng_jnp.prepare_approx(&am).unwrap();
    let prep_p = eng_pal.prepare_approx(&am).unwrap();
    let (dj, _) = eng_jnp.approx_predict(&prep_j, &test.x).unwrap();
    let (dp, _) = eng_pal.approx_predict(&prep_p, &test.x).unwrap();
    for r in 0..test.len() {
        assert!(
            (dj[r] - dp[r]).abs() < tolerance(dj[r]),
            "row {r}: jnp {} vs pallas {}",
            dj[r],
            dp[r]
        );
    }
}
