//! Helpers shared by the socket/process e2e suites (`remote_e2e`,
//! `chaos_e2e`): env-tunable deadlines, poll-with-timeout, and the
//! registry/baseline builders both suites compare decisions against.
//!
//! Timeouts: every wait in these suites derives from one knob,
//! `APPROXRBF_TEST_DEADLINE_MS` (default 30000), so a slow or heavily
//! loaded runner stretches the whole suite with one setting instead
//! of hunting hard-coded constants. Shrinking it below the default is
//! for humans iterating locally, not CI.

#![allow(dead_code)]

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::ApproxModel;
use approxrbf::coordinator::{Coordinator, Route};
use approxrbf::data::{synth, Dataset, UnitNormScaler};
use approxrbf::linalg::MathBackend;
use approxrbf::registry::ModelStore;
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};

/// Plane-wide drift tolerance used on BOTH sides of every comparison
/// (in-process baseline and `serve-shard --drift-tol`), so int8
/// tenants route deterministically.
pub const DRIFT_TOL: &str = "1.0";

/// Base e2e deadline in ms: `APPROXRBF_TEST_DEADLINE_MS`, default
/// 30000. Zero or unparseable values fall back to the default.
pub fn deadline_ms() -> u64 {
    std::env::var("APPROXRBF_TEST_DEADLINE_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .unwrap_or(30_000)
}

/// The base deadline: bounds any single logical wait (a completion
/// drain, a fail-fast sweep, a service-restored poll).
pub fn deadline() -> Duration {
    Duration::from_millis(deadline_ms())
}

/// Double deadline for whole-session waits (`Session::wait_all` over
/// hundreds of requests).
pub fn long_deadline() -> Duration {
    Duration::from_millis(deadline_ms() * 2)
}

/// Short deadline (a third of base) for receiving one completion.
pub fn recv_deadline() -> Duration {
    Duration::from_millis((deadline_ms() / 3).max(1))
}

/// Poll `cond` every 20 ms until it holds or `timeout` elapses;
/// returns whether it ever held (final re-check included, so a
/// slow-but-true condition at the boundary still passes).
pub fn poll_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    cond()
}

/// Fresh per-process scratch dir (removed first if a previous run
/// left it behind). The caller removes it at test end.
pub fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("approxrbf_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Train one (exact, approx) model pair on a synthetic dataset.
pub fn trained_pair(
    seed: u64,
    gamma_mult: f32,
) -> (SvmModel, ApproxModel, Dataset) {
    let ds = synth::two_gaussians(seed, 220, 8, 1.5);
    let scaled = UnitNormScaler.apply_dataset(&ds);
    let gamma = gamma_max_for_data(&scaled) * gamma_mult;
    let (model, _) =
        train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
    (model, am, scaled)
}

/// One served request: (model, generation, decision bits, route).
pub type Served = (String, u64, u32, Route);

/// The in-process `shards(1)` baseline every remote decision must
/// bit-match.
pub fn run_in_process(
    store: &Arc<ModelStore>,
    traffic: &[(&'static str, Vec<f32>)],
) -> Vec<Served> {
    let coord = Coordinator::builder()
        .shards(1)
        .max_wait(Duration::from_millis(1))
        .quant_drift_tol(DRIFT_TOL.parse().unwrap())
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    let mut session = client.session();
    for (id, z) in traffic {
        session.submit_to(id, z.clone()).unwrap();
    }
    let completions = session.wait_all(long_deadline()).unwrap();
    let rows = completions
        .into_iter()
        .map(|c| {
            let r = c.expect("no failures in the baseline workload");
            (r.model.to_string(), r.generation, r.decision.to_bits(), r.route)
        })
        .collect();
    coord.shutdown().unwrap();
    rows
}
