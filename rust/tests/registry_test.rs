//! Registry integration: property-based text ↔ binary codec round
//! trips, corrupted-artifact handling, and the hot-swap acceptance
//! test — publish v1, serve, republish v2 mid-stream, and assert the
//! coordinator switches generations without erroring or dropping any
//! in-flight request.

use std::sync::Arc;
use std::time::Duration;

use approxrbf::approx::builder::build_approx_model;
use approxrbf::approx::bounds::gamma_max_for_data;
use approxrbf::approx::{ApproxModel, RffModel};
use approxrbf::coordinator::{Coordinator, Route, RoutePolicy, TenantPolicy};
use approxrbf::data::{synth, Dataset, UnitNormScaler};
use approxrbf::linalg::{Mat, MathBackend};
use approxrbf::prop_cases;
use approxrbf::registry::{
    binfmt, FormatVersion, MapFile, ModelStore, PayloadKind, PublishOptions,
};
use approxrbf::svm::smo::{train_csvc, SmoParams};
use approxrbf::svm::{Kernel, SvmModel};
use approxrbf::util::Rng;
use approxrbf::Error;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("approxrbf_registry_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ---------------------------------------------------------------------
// property-based codec round trips
// ---------------------------------------------------------------------

fn random_approx(rng: &mut Rng) -> ApproxModel {
    let d = 1 + rng.below(12);
    let mut m = Mat::zeros(d, d);
    for r in 0..d {
        for c in r..d {
            let val = rng.normal() as f32;
            *m.at_mut(r, c) = val;
            *m.at_mut(c, r) = val;
        }
    }
    ApproxModel {
        gamma: rng.range(1e-4, 4.0) as f32,
        b: rng.normal() as f32,
        c: rng.normal() as f32,
        v: (0..d).map(|_| rng.normal() as f32).collect(),
        m,
        max_sv_norm_sq: rng.range(1e-3, 9.0) as f32,
    }
}

fn random_svm(rng: &mut Rng) -> SvmModel {
    let n_sv = 1 + rng.below(8);
    let d = 1 + rng.below(20);
    let mut sv = Mat::zeros(n_sv, d);
    for r in 0..n_sv {
        for c in 0..d {
            // ~60% sparsity exercises the LIBSVM sparse index paths.
            if rng.chance(0.4) {
                *sv.at_mut(r, c) = rng.normal() as f32;
            }
        }
        // Keep the text codec's dim inference honest: the text format
        // recovers d from the largest seen index, so pin the last
        // column of the first row.
        if r == 0 {
            *sv.at_mut(0, d - 1) = 1.0 + rng.uniform() as f32;
        }
    }
    let coef: Vec<f32> = (0..n_sv)
        .map(|i| {
            let mag = 0.1 + rng.uniform() as f32;
            if i % 2 == 0 {
                mag
            } else {
                -mag
            }
        })
        .collect();
    SvmModel::new(
        Kernel::Rbf { gamma: rng.range(1e-3, 2.0) as f32 },
        sv,
        coef,
        rng.normal() as f32,
    )
    .unwrap()
}

fn assert_approx_eq(a: &ApproxModel, b: &ApproxModel) {
    assert_eq!(a.dim(), b.dim());
    assert_eq!(a.gamma, b.gamma);
    assert_eq!(a.b, b.b);
    assert_eq!(a.c, b.c);
    assert_eq!(a.max_sv_norm_sq, b.max_sv_norm_sq);
    assert_eq!(a.v, b.v);
    assert_eq!(a.m.max_abs_diff(&b.m), 0.0);
}

fn assert_svm_eq(a: &SvmModel, b: &SvmModel) {
    assert_eq!(a.kernel, b.kernel);
    assert_eq!(a.b, b.b);
    assert_eq!(a.coef, b.coef);
    assert_eq!(a.dim(), b.dim());
    assert_eq!(a.sv.max_abs_diff(&b.sv), 0.0);
}

#[test]
fn property_approx_text_and_binary_roundtrip_agree() {
    prop_cases!("approx text<->binary", 32, |rng| {
        let am = random_approx(rng);
        // Binary: bit-exact.
        let via_bin =
            binfmt::decode_approx(&binfmt::encode_approx(&am).unwrap())
                .unwrap();
        assert_approx_eq(&am, &via_bin);
        // Text: fmt_f32 guarantees f32-exact round trips too.
        let via_text = ApproxModel::from_text(&am.to_text()).unwrap();
        assert_approx_eq(&am, &via_text);
        // Composition: text -> model -> binary -> model.
        let composed =
            binfmt::decode_approx(&binfmt::encode_approx(&via_text).unwrap())
                .unwrap();
        assert_approx_eq(&am, &composed);
    });
}

#[test]
fn property_svm_text_and_binary_roundtrip_agree() {
    prop_cases!("svm text<->binary", 32, |rng| {
        let m = random_svm(rng);
        let via_bin =
            binfmt::decode_svm(&binfmt::encode_svm(&m).unwrap()).unwrap();
        assert_svm_eq(&m, &via_bin);
        let via_text = SvmModel::from_text(&m.to_text()).unwrap();
        assert_svm_eq(&m, &via_text);
        let composed =
            binfmt::decode_svm(&binfmt::encode_svm(&via_text).unwrap())
                .unwrap();
        assert_svm_eq(&m, &composed);
    });
}

#[test]
fn property_bundle_roundtrip_preserves_upper_triangle_symmetry() {
    prop_cases!("bundle roundtrip", 16, |rng| {
        let am = random_approx(rng);
        let d = am.dim();
        let mut sv = Mat::zeros(2, d);
        for c in 0..d {
            *sv.at_mut(0, c) = rng.normal() as f32;
            *sv.at_mut(1, c) = rng.normal() as f32;
        }
        let exact = SvmModel::new(
            Kernel::Rbf { gamma: am.gamma },
            sv,
            vec![1.0, -1.0],
            am.b,
        )
        .unwrap();
        let generation = rng.below(1000) as u64;
        let bytes = binfmt::encode_bundle(generation, &exact, &am).unwrap();
        let bundle = binfmt::decode_bundle_full(&bytes).unwrap();
        assert_eq!(generation, bundle.generation);
        assert_eq!(bundle.payload(), PayloadKind::F32);
        let back_e = bundle.exact_dequant();
        let back_a = bundle.approx_dequant();
        assert_svm_eq(&exact, &back_e);
        assert_approx_eq(&am, &back_a);
        assert_eq!(bundle.policy, None);
        // Symmetry must survive the upper-triangle-only encoding.
        for r in 0..d {
            for c in 0..d {
                assert_eq!(back_a.m.at(r, c), back_a.m.at(c, r));
            }
        }
    });
}

#[test]
fn property_corrupted_bytes_never_panic_and_are_typed() {
    prop_cases!("corruption fuzz", 48, |rng| {
        let am = random_approx(rng);
        let good = binfmt::encode_approx(&am).unwrap();
        let mut bad = good.clone();
        match rng.below(3) {
            0 => {
                // Bit flip anywhere.
                let at = rng.below(bad.len());
                bad[at] ^= 1 << rng.below(8);
            }
            1 => {
                // Truncate anywhere.
                bad.truncate(rng.below(bad.len()));
            }
            _ => {
                // Append trailing junk.
                bad.push(rng.below(256) as u8);
            }
        }
        if bad == good {
            return; // (possible only for a no-op mutation; skip)
        }
        match binfmt::decode_approx(&bad) {
            Err(Error::Corrupt(_)) => {}
            Err(other) => panic!("wrong error type: {other}"),
            Ok(back) => {
                // A bit flip in a payload f32 would be caught by CRC, so
                // reaching Ok means the mutation must have reproduced a
                // valid encoding — ensure it decodes to the same model.
                assert_approx_eq(&am, &back);
            }
        }
    });
}

fn random_policy(rng: &mut Rng) -> TenantPolicy {
    let route = match rng.below(4) {
        0 => None,
        1 => Some(RoutePolicy::AlwaysApprox),
        2 => Some(RoutePolicy::AlwaysExact),
        _ => Some(RoutePolicy::Hybrid),
    };
    let max_batch = if rng.chance(0.5) {
        Some(1 + rng.below(4096))
    } else {
        None
    };
    // Whole microseconds ≥ 1: the record encodes max_wait in µs and
    // treats 0 as "unset".
    let max_wait = if rng.chance(0.5) {
        Some(std::time::Duration::from_micros(
            1 + rng.below(5_000_000) as u64,
        ))
    } else {
        None
    };
    // Dyadic tolerances so the f32 roundtrip comparison is exact (any
    // finite f32 roundtrips bit-exactly; dyadic just keeps asserts
    // readable). Half the cases exercise the 19-byte v1 body (unset),
    // half the 23-byte v2 body.
    let quant_drift_tol = if rng.chance(0.5) {
        Some(rng.below(64) as f32 / 256.0)
    } else {
        None
    };
    TenantPolicy {
        route,
        max_batch,
        max_wait,
        max_resident_hint: rng.below(16) as u32,
        quant_drift_tol,
    }
}

#[test]
fn property_tenant_policy_roundtrips_through_arbf_record() {
    prop_cases!("policy <-> arbf", 48, |rng| {
        let am = random_approx(rng);
        let d = am.dim();
        let mut sv = Mat::zeros(1, d);
        for c in 0..d {
            *sv.at_mut(0, c) = rng.normal() as f32;
        }
        let exact = SvmModel::new(
            Kernel::Rbf { gamma: am.gamma },
            sv,
            vec![1.0],
            am.b,
        )
        .unwrap();
        let policy = random_policy(rng);
        // The policy record must be bit-stable whatever payload
        // precision carries the models around it.
        for kind in [PayloadKind::F32, PayloadKind::F16, PayloadKind::Int8]
        {
            let bytes = binfmt::encode_bundle_quantized(
                9,
                &exact,
                &am,
                Some(&policy),
                kind,
            )
            .unwrap();
            let hdr = binfmt::peek_header(&bytes).unwrap();
            assert!(hdr.has_policy());
            assert_eq!(hdr.payload(), kind);
            let bundle = binfmt::decode_bundle_full(&bytes).unwrap();
            assert_eq!(
                bundle.policy,
                Some(policy),
                "{kind}: policy must be bit-stable"
            );
            if kind == PayloadKind::F32 {
                assert_approx_eq(&am, &bundle.approx_dequant());
                assert_svm_eq(&exact, &bundle.exact_dequant());
            }
        }
    });
}

#[test]
fn property_policy_roundtrips_through_store_publish() {
    let store = Arc::new(ModelStore::open(temp_dir("prop_policy")).unwrap());
    prop_cases!("policy <-> store", 12, |rng| {
        let (e, a, _) = trained_pair_cached(rng.below(3) as u64);
        let policy = random_policy(rng);
        store
            .publish_with(
                "p",
                &e,
                &a,
                PublishOptions {
                    policy: Some(policy),
                    warm: rng.chance(0.5),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(store.load("p").unwrap().policy, Some(policy));
    });
}

/// Tiny cached trainer so the store property test does not retrain 12
/// SVMs (the models are irrelevant; the policy record is under test).
fn trained_pair_cached(which: u64) -> (SvmModel, ApproxModel, Dataset) {
    use std::sync::OnceLock;
    static CACHE: OnceLock<Vec<(SvmModel, ApproxModel, Dataset)>> =
        OnceLock::new();
    let all = CACHE.get_or_init(|| {
        (0..3u64).map(|s| trained_pair(100 + s, 0.8)).collect()
    });
    all[(which as usize) % all.len()].clone()
}

// ---------------------------------------------------------------------
// per-tenant policy drives the served route mix (acceptance)
// ---------------------------------------------------------------------

#[test]
fn published_policy_overrides_route_and_hot_swaps_away() {
    let store = Arc::new(ModelStore::open(temp_dir("policyroute")).unwrap());
    let (m, a, data) = trained_pair(21, 0.8); // in-bound ⇒ hybrid → approx
    let pinned = TenantPolicy {
        route: Some(RoutePolicy::AlwaysExact),
        ..Default::default()
    };
    // f32-pinned payloads: this test asserts an exact route mix and
    // in_bound flags, which a quantized payload's folded drift budget
    // could legitimately shift.
    store
        .publish_with(
            "tenant",
            &m,
            &a,
            PublishOptions {
                policy: Some(pinned),
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    let coord = Coordinator::builder()
        .policy(RoutePolicy::Hybrid)
        .swap_poll(Duration::from_millis(5))
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    let sub = data.x.rows_slice(0, 30);
    // The bundle's policy pins every (in-bound!) instance to the exact
    // path, overriding the coordinator-wide hybrid default.
    let r1 = client.predict_all_for("tenant", &sub).unwrap();
    assert!(r1.iter().all(|r| r.route == Route::Exact && r.in_bound));
    // Republish without a policy: the hot swap restores hybrid routing.
    store
        .publish_with(
            "tenant",
            &m,
            &a,
            PublishOptions {
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    coord.refresh();
    // The refresh epoch is observed on the tenant's next batch.
    let r2 = client.predict_all_for("tenant", &sub).unwrap();
    assert_eq!(r2[0].generation, 2);
    assert!(r2.iter().all(|r| r.route == Route::Approx));
    let snap = coord.metrics();
    assert_eq!(snap.per_model[0].served_exact, 30);
    assert_eq!(snap.per_model[0].served_approx, 30);
    coord.shutdown().unwrap();
}

// ---------------------------------------------------------------------
// registry GC + rollback through the serving path
// ---------------------------------------------------------------------

#[test]
fn rollback_is_served_like_any_hot_swap() {
    let store = Arc::new(ModelStore::open(temp_dir("rollbackserve")).unwrap());
    let (m1, a1, data) = trained_pair(31, 0.8);
    let (m2, a2, _) = trained_pair(32, 0.7);
    store.publish("tenant", &m1, &a1).unwrap();
    // Reference the served state (whatever payload kind the publish
    // used — APPROXRBF_TEST_QUANT may quantize it).
    let gen1 = store.load("tenant").unwrap();
    store.publish("tenant", &m2, &a2).unwrap();
    let coord = Coordinator::builder()
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    let sub = data.x.rows_slice(0, 10);
    let before = client.predict_all_for("tenant", &sub).unwrap();
    assert!(before.iter().all(|r| r.generation == 2));
    // v2 is bad: revert. The rollback republishes v1's payload as
    // generation 3 — monotone, so the swap detector fires normally.
    assert_eq!(store.rollback("tenant").unwrap(), 3);
    coord.refresh();
    let after = client.predict_all_for("tenant", &sub).unwrap();
    assert!(after.iter().all(|r| r.generation == 3));
    for (i, resp) in after.iter().enumerate() {
        let want = match resp.route {
            Route::Approx => gen1.approx_decision_one(sub.row(i)),
            Route::Exact => gen1.exact_decision_one(sub.row(i)),
        };
        assert!(
            (resp.decision - want).abs() < 1e-3,
            "rollback must serve v1's weights"
        );
    }
    coord.shutdown().unwrap();
}

// ---------------------------------------------------------------------
// hot swap (acceptance)
// ---------------------------------------------------------------------

fn trained_pair(
    seed: u64,
    gamma_mult: f32,
) -> (SvmModel, ApproxModel, Dataset) {
    let ds = synth::two_gaussians(seed, 220, 8, 1.5);
    let scaled = UnitNormScaler.apply_dataset(&ds);
    let gamma = gamma_max_for_data(&scaled) * gamma_mult;
    let (model, _) =
        train_csvc(&scaled, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let am = build_approx_model(&model, MathBackend::Blocked).unwrap();
    (model, am, scaled)
}

#[test]
fn hot_swap_switches_generations_without_dropping_requests() {
    let store = Arc::new(ModelStore::open(temp_dir("hotswap")).unwrap());
    let (m1, a1, data) = trained_pair(5, 0.8);
    let (m2, a2, _) = trained_pair(77, 0.7); // same d, different model
    assert_eq!(store.publish("tenant", &m1, &a1).unwrap(), 1);
    // Reference entries for both generations (payload-kind agnostic:
    // under APPROXRBF_TEST_QUANT these are the quantized served state).
    let gen1 = store.load("tenant").unwrap();

    let coord = Coordinator::builder()
        .max_wait(Duration::from_millis(1))
        .swap_poll(Duration::from_millis(5))
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();

    let rows = 100usize.min(data.len());
    let half = 150usize;
    let total = 2 * half;
    let mut row_of = Vec::with_capacity(total);
    let mut responses = Vec::with_capacity(total);

    // Phase A: stream the first half against v1.
    for i in 0..half {
        let row = i % rows;
        let id = client
            .submit_to("tenant", data.x.row(row).to_vec())
            .expect("submit must never fail across the swap");
        assert_eq!(id as usize, i);
        row_of.push(row);
    }
    // Wait until v1 has demonstrably served traffic, leaving the rest
    // of phase A in flight.
    while responses.len() < half / 3 {
        let r = client
            .recv(Duration::from_secs(10))
            .expect("response lost before swap")
            .expect("no error completions across the swap");
        responses.push(r);
    }

    // Phase B: with requests still in flight, atomically publish v2
    // under the same id and force the coordinator to notice.
    assert_eq!(store.publish("tenant", &m2, &a2).unwrap(), 2);
    let gen2 = store.load("tenant").unwrap();
    coord.refresh();

    // Phase C: stream the second half; these are submitted strictly
    // after the refresh, so the executor revalidates before serving
    // them — they must all come back as generation 2.
    for i in half..total {
        let row = i % rows;
        let id = client
            .submit_to("tenant", data.x.row(row).to_vec())
            .expect("submit must never fail across the swap");
        assert_eq!(id as usize, i);
        row_of.push(row);
    }
    while responses.len() < total {
        let r = client
            .recv(Duration::from_secs(10))
            .expect("response lost across hot swap")
            .expect("no error completions across the swap");
        responses.push(r);
    }

    // Every request answered exactly once.
    let mut seen = std::collections::HashSet::new();
    for r in &responses {
        assert!(seen.insert(r.id), "duplicate id {}", r.id);
        assert!((r.id as usize) < total);
    }
    assert_eq!(seen.len(), total);

    // Every response is numerically correct for the generation that
    // served it — no torn reads, no mixed state.
    let mut gen_counts = [0usize; 3];
    for r in &responses {
        let row = row_of[r.id as usize];
        let z = data.x.row(row);
        let want = match (r.generation, r.route) {
            (1, Route::Approx) => gen1.approx_decision_one(z),
            (1, Route::Exact) => gen1.exact_decision_one(z),
            (2, Route::Approx) => gen2.approx_decision_one(z),
            (2, Route::Exact) => gen2.exact_decision_one(z),
            (g, _) => panic!("unexpected generation {g}"),
        };
        assert!(
            (r.decision - want).abs() < 1e-3,
            "id {} gen {}: {} vs {want}",
            r.id,
            r.generation,
            r.decision
        );
        gen_counts[r.generation as usize] += 1;
        // Phase C was submitted after the refresh: the swap must have
        // taken effect for every one of those requests.
        if r.id as usize >= half {
            assert_eq!(
                r.generation, 2,
                "post-refresh request {} served by generation {}",
                r.id, r.generation
            );
        }
    }
    // Both generations actually served traffic (the swap happened
    // mid-stream, not before/after the run).
    assert!(gen_counts[1] > 0, "generation 1 served nothing");
    assert!(gen_counts[2] >= half, "generation 2 served nothing");

    // Per-model metrics accounted for the tenant.
    let snap = coord.metrics();
    assert_eq!(snap.per_model.len(), 1);
    assert_eq!(snap.per_model[0].id, "tenant");
    assert!(snap.per_model[0].served_total() as usize >= total);
    coord.shutdown().unwrap();
}

// ---------------------------------------------------------------------
// quantized payloads: codec properties + full serving path (acceptance)
// ---------------------------------------------------------------------

#[test]
fn property_quantized_bundles_roundtrip_within_bounds_and_reencode_stably() {
    prop_cases!("quant bundle roundtrip", 24, |rng| {
        let am = random_approx(rng);
        let d = am.dim();
        let mut sv = Mat::zeros(2, d);
        for c in 0..d {
            *sv.at_mut(0, c) = rng.normal() as f32;
            *sv.at_mut(1, c) = rng.normal() as f32;
        }
        let exact = SvmModel::new(
            Kernel::Rbf { gamma: am.gamma },
            sv,
            vec![1.0, -1.0],
            am.b,
        )
        .unwrap();
        for kind in [PayloadKind::F16, PayloadKind::Int8] {
            let bytes = binfmt::encode_bundle_quantized(
                4, &exact, &am, None, kind,
            )
            .unwrap();
            let bundle = binfmt::decode_bundle_full(&bytes).unwrap();
            assert_eq!(bundle.payload(), kind);
            // Dequantized tensors stay within the advertised per-element
            // bounds of their sources.
            let err = bundle.models.quant_error().unwrap();
            let back = bundle.approx_dequant();
            for (i, (&x, &y)) in am.v.iter().zip(&back.v).enumerate() {
                assert!(
                    (x - y).abs() <= err.eps_v,
                    "{kind} v[{i}]: |{x} - {y}| > {}",
                    err.eps_v
                );
            }
            assert!(back.m.max_abs_diff(&am.m) <= err.eps_m);
            // Native re-encode is byte-stable (no requantization).
            let again = binfmt::encode_bundle_native(
                4,
                &bundle.models,
                bundle.policy.as_ref(),
            )
            .unwrap();
            assert_eq!(again, bytes, "{kind}");
            // Quantized record corruption stays typed, never panics.
            let mut bad = bytes.clone();
            let at = rng.below(bad.len());
            bad[at] ^= 1 << rng.below(8);
            if bad != bytes {
                if let Err(e) = binfmt::decode_bundle_full(&bad) {
                    assert!(
                        matches!(e, Error::Corrupt(_)),
                        "{kind}: wrong error type {e}"
                    );
                }
            }
        }
    });
}

/// The zero-copy acceptance: across kernels, payload precisions and
/// the rff substrate, a bundle decoded over its v2 mapped backing
/// produces decisions **bit-identical** to the v1 heap decode of the
/// same model — storage (borrowed views vs owned vectors) must never
/// leak into arithmetic.
#[test]
fn property_v2_mapped_decisions_bit_identical_to_v1_heap() {
    prop_cases!("v1 heap == v2 mmap", 12, |rng| {
        let am = random_approx(rng);
        let d = am.dim();
        let mut sv = Mat::zeros(2, d);
        for c in 0..d {
            *sv.at_mut(0, c) = rng.normal() as f32;
            *sv.at_mut(1, c) = rng.normal() as f32;
        }
        let kernel = match rng.below(3) {
            0 => Kernel::Linear,
            1 => Kernel::Rbf { gamma: am.gamma },
            _ => Kernel::Poly2 { gamma: am.gamma, beta: 0.5 },
        };
        let exact =
            SvmModel::new(kernel, sv, vec![1.0, -1.0], am.b).unwrap();
        let queries: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        let assert_twin = |v1: Vec<u8>, v2: Vec<u8>, what: &str| {
            let heap = binfmt::decode_bundle_full(&v1).unwrap();
            let map = Arc::new(MapFile::from_bytes(v2));
            let mapped = binfmt::decode_bundle_mapped(&map).unwrap();
            assert_eq!(mapped.format, FormatVersion::V2, "{what}");
            for z in &queries {
                let a0 = heap.models.approx_decision_one(z);
                let a1 = mapped.models.approx_decision_one(z);
                assert_eq!(a0.to_bits(), a1.to_bits(), "{what}: approx");
                let e0 = heap.models.exact_decision_one(z);
                let e1 = mapped.models.exact_decision_one(z);
                assert_eq!(e0.to_bits(), e1.to_bits(), "{what}: exact");
            }
            mapped.models.mapped_bytes()
        };
        for kind in [PayloadKind::F32, PayloadKind::F16, PayloadKind::Int8] {
            let v1 = binfmt::encode_bundle_quantized(5, &exact, &am, None, kind)
                .unwrap();
            let v2 = binfmt::encode_bundle_quantized_at(
                5,
                &exact,
                &am,
                None,
                kind,
                FormatVersion::V2,
            )
            .unwrap();
            let mapped_bytes = assert_twin(v1, v2, &format!("{kind}"));
            if cfg!(target_endian = "little") && kind != PayloadKind::F32 {
                assert!(mapped_bytes > 0, "{kind}: expected mapped views");
            }
        }
        // The rff substrate: identical stored weights (and seed, so an
        // identical regenerated feature map) under both containers.
        let n_feat = 4 * (1 + rng.below(8));
        let w: Vec<f32> = (0..n_feat).map(|_| rng.normal() as f32).collect();
        let rff = RffModel::from_parts(
            d,
            1 + rng.below(1 << 20) as u64,
            am.gamma,
            rng.normal() as f32,
            0.25,
            w,
        )
        .unwrap();
        let v1 = binfmt::encode_bundle_rff(5, &exact, &am, &rff, None).unwrap();
        let v2 = binfmt::encode_bundle_rff_at(
            5,
            &exact,
            &am,
            &rff,
            None,
            FormatVersion::V2,
        )
        .unwrap();
        let mapped_bytes = assert_twin(v1, v2, "rff");
        if cfg!(target_endian = "little") {
            assert!(mapped_bytes > 0, "rff: expected mapped weights");
        }
    });
}

/// The ISSUE's serving acceptance: an int8 bundle publishes, decodes,
/// hot-swaps (f32 → int8 mid-stream) and serves through `Client`, with
/// every approx-routed decision within the bound `approx/bounds.rs`
/// reports of the f32 twin's decision.
#[test]
fn int8_bundle_serves_within_reported_bound_and_hot_swaps_from_f32() {
    let store = Arc::new(ModelStore::open(temp_dir("quantserve")).unwrap());
    let (m, a, data) = trained_pair(61, 0.8);
    // Generation 1: f32. Generation 2 (mid-stream): int8, same weights.
    store
        .publish_with(
            "tenant",
            &m,
            &a,
            PublishOptions {
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    let coord = Coordinator::builder()
        .max_wait(Duration::from_millis(1))
        .swap_poll(Duration::from_millis(5))
        // Generous tolerance so the int8 tenant deterministically keeps
        // a usable approx budget (the zero-tolerance companion test
        // below pins the escort direction).
        .quant_drift_tol(1.0)
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    let sub = data.x.rows_slice(0, 40);
    let r1 = client.predict_all_for("tenant", &sub).unwrap();
    assert!(r1.iter().all(|r| r.generation == 1));

    store
        .publish_with(
            "tenant",
            &m,
            &a,
            PublishOptions {
                quantize: Some(PayloadKind::Int8),
                ..Default::default()
            },
        )
        .unwrap();
    let entry = store.load("tenant").unwrap();
    assert_eq!(entry.payload(), PayloadKind::Int8);
    let q = entry.quant_info().expect("int8 entry carries quant info");
    coord.refresh();
    let r2 = client.predict_all_for("tenant", &sub).unwrap();
    assert!(r2.iter().all(|r| r.generation == 2), "hot swap to int8");
    let mut approx_served = 0;
    for (i, resp) in r2.iter().enumerate() {
        // Served decision == the native quantized evaluation…
        let want = match resp.route {
            Route::Approx => entry.approx_decision_one(sub.row(i)),
            Route::Exact => entry.exact_decision_one(sub.row(i)),
        };
        assert!((resp.decision - want).abs() < 1e-3);
        // …and within the reported drift bound of the f32 twin (the
        // exact-side bound is z-aware: int8 kernels evaluate against
        // an i16-quantized query, which adds a ‖z‖-scaled term).
        match resp.route {
            Route::Approx => {
                approx_served += 1;
                let (f32_dec, zn) = a.decision_one(sub.row(i));
                assert!(
                    (resp.decision - f32_dec).abs()
                        <= q.approx_err.decision_error(zn),
                    "row {i}: int8 drift exceeds the reported bound"
                );
            }
            Route::Exact => {
                let f32_dec = m.decision_one(sub.row(i));
                let zn = approxrbf::linalg::vecops::norm_sq(sub.row(i));
                assert!(
                    (resp.decision - f32_dec).abs()
                        <= q.exact_err.decision_error_at(zn),
                    "row {i}: int8 exact drift exceeds the reported bound"
                );
            }
        }
    }
    // The quantized tenant still rides the fast path for this
    // well-conditioned model (the drift budget did not collapse).
    assert!(approx_served > 0, "int8 tenant never served approx");
    coord.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(store.root());
}

/// The router really folds quantization into the budget: with a zero
/// drift tolerance, a quantized tenant's Hybrid budget collapses and
/// every instance is escorted to the exact path (its f32 twin, served
/// by the same plane, keeps riding approx).
#[test]
fn zero_drift_tolerance_escorts_quantized_tenant_to_exact() {
    let store = Arc::new(ModelStore::open(temp_dir("quanttol")).unwrap());
    let (m, a, data) = trained_pair(62, 0.8);
    store
        .publish_with(
            "q8",
            &m,
            &a,
            PublishOptions {
                quantize: Some(PayloadKind::Int8),
                ..Default::default()
            },
        )
        .unwrap();
    store
        .publish_with(
            "f32",
            &m,
            &a,
            PublishOptions {
                quantize: Some(PayloadKind::F32),
                ..Default::default()
            },
        )
        .unwrap();
    let coord = Coordinator::builder()
        .policy(RoutePolicy::Hybrid)
        .quant_drift_tol(0.0)
        .start_registry(store.clone())
        .unwrap();
    let client = coord.client();
    let sub = data.x.rows_slice(0, 20);
    let rq = client.predict_all_for("q8", &sub).unwrap();
    assert!(
        rq.iter().all(|r| r.route == Route::Exact && !r.in_bound),
        "zero tolerance must escort every quantized instance"
    );
    // The f32 twin is untouched by the tolerance (no quant error).
    let rf = client.predict_all_for("f32", &sub).unwrap();
    assert!(rf.iter().all(|r| r.route == Route::Approx && r.in_bound));
    coord.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(store.root());
}

#[test]
fn registry_serving_isolates_tenant_dimensions() {
    let store = Arc::new(ModelStore::open(temp_dir("dims")).unwrap());
    let (m8, a8, d8) = trained_pair(9, 0.8);
    let ds12 = synth::two_gaussians(13, 200, 12, 1.5);
    let sc12 = UnitNormScaler.apply_dataset(&ds12);
    let gamma = gamma_max_for_data(&sc12) * 0.8;
    let (m12, _) =
        train_csvc(&sc12, Kernel::Rbf { gamma }, SmoParams::default())
            .unwrap();
    let a12 = build_approx_model(&m12, MathBackend::Blocked).unwrap();
    store.publish("eight", &m8, &a8).unwrap();
    store.publish("twelve", &m12, &a12).unwrap();
    let ent8 = store.load("eight").unwrap();
    let ent12 = store.load("twelve").unwrap();

    let coord = Coordinator::builder().start_registry(store).unwrap();
    let client = coord.client();
    // Wrong-dimension submits are rejected per tenant at the boundary.
    assert!(client.submit_to("eight", vec![0.0; 12]).is_err());
    assert!(client.submit_to("twelve", vec![0.0; 8]).is_err());
    let r8 = client
        .predict_all_for("eight", &d8.x.rows_slice(0, 16))
        .unwrap();
    let r12 = client
        .predict_all_for("twelve", &sc12.x.rows_slice(0, 16))
        .unwrap();
    for (i, resp) in r8.iter().enumerate() {
        let want = match resp.route {
            Route::Approx => ent8.approx_decision_one(d8.x.row(i)),
            Route::Exact => ent8.exact_decision_one(d8.x.row(i)),
        };
        assert!((resp.decision - want).abs() < 1e-3);
    }
    for (i, resp) in r12.iter().enumerate() {
        let want = match resp.route {
            Route::Approx => ent12.approx_decision_one(sc12.x.row(i)),
            Route::Exact => ent12.exact_decision_one(sc12.x.row(i)),
        };
        assert!((resp.decision - want).abs() < 1e-3);
    }
    coord.shutdown().unwrap();
}
